"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward/train step and a prefill+decode step on
CPU, asserting output shapes and the absence of NaNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REDUCTIONS, reduced_cfg
from repro.config import assigned_archs, get_shape, applicable_shapes, get_arch
from repro.models.api import build_model

ARCHS = list(assigned_archs())


def make_batch(cfg, B=2, S=16, with_labels=True):
    batch = {"tokens": jnp.arange(B * S).reshape(B, S).astype(jnp.int32)
             % cfg.vocab}
    if with_labels:
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(9), (B, cfg.vlm.n_img_tokens, cfg.d_model)
        ).astype(cfg.dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.key(9), (B, cfg.encdec.n_audio_frames, cfg.d_model)
        ).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss_fn(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    logits = model.forward(params, batch)
    B, S = batch["tokens"].shape
    S_out = S + (cfg.vlm.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_padded())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    """One gradient step: finite grads, params change."""
    cfg = reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat), f"{arch}: non-finite grads"
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
               for g in flat), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S, with_labels=False)
    logits, cache = model.prefill(params, batch, 32)
    assert logits.shape == (B, cfg.vocab_padded())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits[..., :cfg.vocab], -1)[:, None].astype(jnp.int32)
    lg2, cache = model.decode_step(params, cache, tok, jnp.int32(S))
    assert lg2.shape == (B, cfg.vocab_padded())
    assert bool(jnp.all(jnp.isfinite(lg2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "olmo-1b", "deepseek-coder-33b"])
def test_decode_matches_teacher_forcing(arch):
    """Transformer prefill+decode path must agree with the full forward
    (same tokens, same positions) — the KV-cache correctness oracle."""
    cfg = reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(5), (B, S + 1), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks,
                                  "labels": jnp.zeros_like(toks)})
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :S]}, S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, S - 1], np.float32), rtol=2e-2, atol=2e-2)
    lg, _ = model.decode_step(params, cache, toks[:, S:S + 1].astype(jnp.int32),
                              jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full[:, S], np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_applicable_shapes(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    for shape_name in applicable_shapes(cfg):
        specs = model.input_specs(get_shape(shape_name))
        assert "tokens" in specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_long_500k_applicability_matches_design():
    """DESIGN.md §4: long_500k runs only for sub-quadratic archs."""
    runs = {a for a in ARCHS
            if "long_500k" in applicable_shapes(get_arch(a))}
    assert runs == {"xlstm-1.3b", "zamba2-7b", "mixtral-8x22b"}


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mistral-large-123b"])
def test_int8_kv_cache_decode_parity(arch):
    """kv_bits=8 decode must stay within 5% of the bf16-cache logits."""
    cfg16 = reduced_cfg(arch)
    cfg8 = cfg16.scaled(kv_bits=8)
    m16, m8 = build_model(cfg16), build_model(cfg8)
    params = m16.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(5), (B, S + 1), 0, cfg16.vocab)
    _, c16 = m16.prefill(params, {"tokens": toks[:, :S]}, S + 4)
    _, c8 = m8.prefill(params, {"tokens": toks[:, :S]}, S + 4)
    step = toks[:, S:S + 1].astype(jnp.int32)
    d16, _ = m16.decode_step(params, c16, step, jnp.int32(S))
    d8, _ = m8.decode_step(params, c8, step, jnp.int32(S))
    rel = float(jnp.max(jnp.abs((d8 - d16).astype(jnp.float32)))) / \
        float(jnp.max(jnp.abs(d16.astype(jnp.float32))))
    assert rel < 0.05, f"{arch}: int8 KV too lossy ({rel:.3f})"
