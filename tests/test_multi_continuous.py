"""Multi-LLM continuous serving: shared-node slot pools, the joint
admission oracle, per-cohort ``quant=auto``, and cross-runtime
conservation.

The load-bearing extension of tests/test_continuous_runtime.py to
multi-model traffic: random ``model_id`` assignment over 2-3 hosted
models, run against BOTH ``EpochRuntime`` and ``ContinuousRuntime`` for
every ``multi-dftsp`` spec variant (orders, pinned method, and
``quant=auto``) — the queue lifecycle must conserve requests
(``arrived == served + dropped + queued``) and never serve a rid twice.
On top: admission on a ``MultiLLMEnv`` is gated by the authoritative
joint ``multi_feasible`` oracle (a per-model-only validate() raises
``InfeasibleDecisionError``, it does not serve), and refills into a
shared node clamp to the target cohort's OWN remaining headroom — the
historical cross-cohort MIN clamp is gone (paged-arena PR; cross-cohort
memory pressure now lives in per-block admission, tests in
test_kv_arena.py).
"""
from __future__ import annotations

import pytest

from repro.core import comm, problem
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, random_tagger
from repro.core.policy import (Decision, InfeasibleDecisionError,
                               SchedulerPolicy)
from repro.core.quantization import METHODS
from repro.core.request import ReplayGenerator, Request, RequestGenerator
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   AnalyticExecutor, ContinuousRuntime,
                                   EngineContinuousExecutor, EpochRuntime)

HOSTED = ("bloom-3b", "bloom-7b1", "opt-13b")
# every multi-dftsp registry variant the repo ships: visit orders, a
# pinned METHODS override, and the adaptive per-cohort selection
MULTI_SPECS = ["multi-dftsp", "multi-dftsp:order=name",
               "multi-dftsp:order=load", "multi-dftsp:quant=W8A8",
               "multi-dftsp:quant=auto"]


def make_menv(n_models=3):
    return MultiLLMEnv.host({m: paper_env(m, "W8A16")
                             for m in HOSTED[:n_models]})


def assert_conserved(m):
    assert m.arrived == m.served + m.dropped + len(m.final_queue_rids), \
        (m.arrived, m.served, m.dropped, len(m.final_queue_rids))


def served_rids(m):
    continuous = any(t.segments for t in m.traces)
    pick = (lambda t: t.finished_rids) if continuous \
        else (lambda t: t.selected_rids)
    return [rid for t in m.traces if t.counted for rid in pick(t)]


def _check_run(m):
    assert_conserved(m)
    rids = served_rids(m)
    assert len(rids) == len(set(rids)) == m.served
    assert sum(m.served_by_model.values()) == m.served


# -- deterministic conservation over every multi-dftsp spec ------------------


@pytest.mark.parametrize("spec", MULTI_SPECS)
@pytest.mark.parametrize("n_models", [2, 3])
def test_multi_conservation_both_runtimes(spec, n_models):
    menv = make_menv(n_models)
    tagger = random_tagger(sorted(menv.envs), seed=3)
    epoch = EpochRuntime(menv, spec, AnalyticExecutor()).run(
        rate=4, n_epochs=4, seed=7, warmup_epochs=0, tag_arrivals=tagger)
    cont = ContinuousRuntime(menv, spec,
                             AnalyticContinuousExecutor(capacity=4),
                             k=64).run(rate=4, n_epochs=4, seed=7,
                                       warmup_epochs=0, tag_arrivals=tagger)
    for m in (epoch, cont):
        _check_run(m)


def test_random_tagger_is_stateless_across_slicings():
    """The epoch loop tags per epoch, the continuous loop per segment:
    the assignment must depend only on (seed, rid)."""
    gen = RequestGenerator(rate=6, seed=0)
    reqs = gen.within(0.0, 4.0)
    whole = random_tagger(HOSTED, seed=5)(
        [r for r in reqs])
    ids_whole = [r.model_id for r in whole]
    sliced = []
    tag2 = random_tagger(HOSTED, seed=5)
    for r in reqs:
        sliced.extend(tag2([r]))
    assert [r.model_id for r in sliced] == ids_whole


# -- the hypothesis property over random multi-model streams -----------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(spec=st.sampled_from(MULTI_SPECS),
           seed=st.integers(0, 2**16),
           rate=st.floats(0.5, 4.0),
           capacity=st.integers(1, 8),
           k=st.sampled_from([1, 64, 256, 512]),
           n_models=st.integers(2, 3))
    def test_multi_conservation_property(spec, seed, rate, capacity, k,
                                         n_models):
        menv = make_menv(n_models)
        tagger = random_tagger(sorted(menv.envs), seed=seed)
        epoch = EpochRuntime(menv, spec, AnalyticExecutor()).run(
            rate=rate, n_epochs=4, seed=seed, warmup_epochs=0,
            tag_arrivals=tagger)
        cont = ContinuousRuntime(
            menv, spec, AnalyticContinuousExecutor(capacity=capacity),
            k=k).run(rate=rate, n_epochs=4, seed=seed, warmup_epochs=0,
                     tag_arrivals=tagger)
        for m in (epoch, cont):
            _check_run(m)


# -- per-cohort quant=auto on the continuous path ----------------------------


def test_continuous_quant_auto_records_cohort_methods():
    """Each freshly started cohort's method lands in EpochTrace.quants
    and served_by_method uses METHODS names (not env defaults only)."""
    menv = make_menv(2)
    tagger = random_tagger(sorted(menv.envs), seed=1)
    m = ContinuousRuntime(menv, "multi-dftsp:quant=auto",
                          AnalyticContinuousExecutor(capacity=4),
                          k=128).run(rate=5, n_epochs=4, seed=1,
                                     warmup_epochs=0, tag_arrivals=tagger)
    _check_run(m)
    assert m.served > 0
    recorded = {q for t in m.traces for q in t.quants.values()}
    assert recorded and recorded <= set(METHODS)
    assert set(m.served_by_method) <= set(METHODS)


def test_continuous_pinned_quant_validates_under_that_method():
    """A pinned method flows through admission validation and into the
    accounting: every served request is labelled with it."""
    menv = make_menv(2)
    tagger = random_tagger(sorted(menv.envs), seed=1)
    m = ContinuousRuntime(menv, "multi-dftsp:quant=W8A8",
                          AnalyticContinuousExecutor(capacity=4),
                          k=128).run(rate=5, n_epochs=4, seed=1,
                                     warmup_epochs=0, tag_arrivals=tagger)
    _check_run(m)
    assert m.served > 0
    assert set(m.served_by_method) == {"W8A8"}


# -- the joint oracle: per-model feasibility must not compose ----------------


class PerModelOnlyPolicy(SchedulerPolicy):
    """Cheating stub: validates each model's batch against ITS OWN
    single-model P1 view and ignores the shared node budgets — exactly
    the mistake the joint oracle exists to catch."""

    name = "per-model-only-stub"

    def schedule(self, env, queue):
        return Decision(batches={m: [] for m in env.envs})

    def validate(self, menv, decision):
        return all(problem.feasible(menv.envs[mid], batch)
                   for mid, batch in decision.batches.items()
                   if mid in menv.envs)


def _hog_request(env, rid, model_id, target=0.6):
    """A request whose uplink share alone is ~``target`` of the shared
    spectrum: per-model feasible, pairwise jointly infeasible."""
    h = 1e-6
    r = Request(rid=rid, s=512, n=4, tau=50.0, a=0.0, h=h, arrival=0.0,
                model_id=model_id)
    while comm.rho_min_up(env, r) < target:
        h *= 0.8
        r.h = h
    rho = comm.rho_min_up(env, r)
    assert target <= rho < 1.0, rho
    assert problem.feasible(env, [r])
    return r


def test_jointly_infeasible_admission_raises_not_serves():
    menv = make_menv(2)
    reqs = [_hog_request(menv.envs["bloom-3b"], 0, "bloom-3b"),
            _hog_request(menv.envs["bloom-7b1"], 1, "bloom-7b1")]
    rt = ContinuousRuntime(menv, PerModelOnlyPolicy(),
                           AnalyticContinuousExecutor(capacity=4), k=128)
    with pytest.raises(InfeasibleDecisionError, match="multi_feasible"):
        rt.run(gen=ReplayGenerator(reqs), n_epochs=2, seed=0,
               warmup_epochs=0)


def test_honest_joint_policy_defers_instead_of_raising():
    """The same jointly-infeasible pair under the honest multi-dftsp
    oracle: the second hog is simply NOT admitted while the first is
    resident — no raise, conservation intact."""
    menv = make_menv(2)
    reqs = [_hog_request(menv.envs["bloom-3b"], 0, "bloom-3b"),
            _hog_request(menv.envs["bloom-7b1"], 1, "bloom-7b1")]
    m = ContinuousRuntime(menv, "multi-dftsp",
                          AnalyticContinuousExecutor(capacity=4),
                          k=128).run(gen=ReplayGenerator(reqs), n_epochs=2,
                                     seed=0, warmup_epochs=0)
    _check_run(m)
    assert m.arrived == 2


# -- shared-node refill headroom clamp ---------------------------------------


@pytest.fixture(scope="module")
def node_engines():
    from repro.serving.engine import tiny_engine
    return {arch: tiny_engine(arch, batch_capacity=2, s_max=8, n_max=8)
            for arch in ("bloom-3b", "bloom-7b1")}


def test_refill_headroom_is_per_cohort_not_node_min(node_engines):
    """Regression for the min-headroom clamp REMOVAL: a refill into
    cohort B is bounded by B's OWN remaining headroom, and another
    cohort's age no longer throttles it — crafted state: A at t=5, B at
    t=2, n_max=8 => B's window is 6 (its own 8-2), NOT the old node-min
    of 3 (A's 8-5)."""
    ea, eb = node_engines["bloom-3b"], node_engines["bloom-7b1"]
    ex = EngineContinuousExecutor(node_engines, seed=0)
    menv = make_menv(2)
    ex.bind(menv)
    pa, pb = ex._pools["bloom-3b"], ex._pools["bloom-7b1"]
    ra = Request(rid=0, s=3, n=8, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-3b")
    rb = Request(rid=1, s=2, n=8, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-7b1")
    pa["state"], pa["t"] = ea.start_chunked([[1, 2, 3]], [8]), 5
    pa["resident"][0] = ra
    pb["state"], pb["t"] = eb.start_chunked([[4, 5]], [8]), 2
    pb["resident"][0] = rb
    assert ex.node_headroom("bloom-7b1") == 6        # own 8-2, NOT min 3
    assert ex.node_headroom("bloom-3b") == 3         # own 8-5

    # the long-running cohort A no longer blocks B's admission: a
    # candidate that fits B's own window (n=6 <= 6) is accepted even
    # though A's remaining headroom is only 3...
    fits_b = Request(rid=3, s=2, n=6, tau=50.0, a=0.0, h=1.0,
                     model_id="bloom-7b1")
    assert ex.accepts("bloom-7b1", fits_b)
    # ...while one that overruns B's own window is still refused
    hungry = Request(rid=2, s=2, n=8, tau=50.0, a=0.0, h=1.0,
                     model_id="bloom-7b1")
    assert not ex.accepts("bloom-7b1", hungry)
    # the clamp itself is defense in depth: force the refill anyway
    ex.place("bloom-7b1", hungry)
    ex.step(menv, 1)
    assert pb["state"].caps_host[1] == 6             # pinned: OWN headroom


def test_fresh_cohort_keeps_full_headroom(node_engines):
    """A cohort STARTING on a shared node is a new provisioning window:
    its rows get their engine's full n_max, not the min clamp."""
    ex = EngineContinuousExecutor(node_engines, seed=0)
    menv = make_menv(2)
    ex.bind(menv)
    pa = ex._pools["bloom-3b"]
    ra = Request(rid=0, s=3, n=8, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-3b")
    pa["state"], pa["t"] = \
        node_engines["bloom-3b"].start_chunked([[1, 2, 3]], [8]), 5
    pa["resident"][0] = ra
    rb = Request(rid=1, s=2, n=8, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-7b1")
    assert ex.accepts("bloom-7b1", rb)               # fresh pool: full n_max
    ex.place("bloom-7b1", rb)
    ex.step(menv, 1)
    assert ex._pools["bloom-7b1"]["state"].caps_host[0] == 8


# -- engine-backed multi-LLM continuous end to end ---------------------------


@pytest.fixture(scope="module")
def serving_engines():
    from repro.serving.engine import tiny_engine
    return {arch: tiny_engine(arch, batch_capacity=4, s_max=16, n_max=8)
            for arch in ("bloom-3b", "bloom-7b1")}


def test_multi_engine_continuous_end_to_end(serving_engines):
    menv = make_menv(2)
    tagger = random_tagger(sorted(menv.envs), seed=0)
    gen = RequestGenerator(rate=6, seed=0, lengths=(2, 4, 8))
    ex = EngineContinuousExecutor(serving_engines, seed=0,
                                  collect_tokens=True)
    m = ContinuousRuntime(menv, "multi-dftsp:quant=auto", ex, k=2).run(
        gen=gen, n_epochs=3, seed=0, warmup_epochs=0, tag_arrivals=tagger)
    _check_run(m)
    assert m.served > 0 and m.generated_tokens > 0
    assert set(m.served_by_model) <= set(menv.envs)
    # per-cohort selections recorded and actually served: the engines'
    # precision sets reflect the decided methods' weight bits
    recorded = {q for t in m.traces for q in t.quants.values()}
    assert recorded and recorded <= set(METHODS)
    bits_decided = {METHODS[q].weight_bits for q in recorded}
    bits_served = set().union(*(e.precisions_served
                                for e in serving_engines.values()))
    assert bits_served <= {0 if b >= 16 else b for b in bits_decided}
    # collected per-request outputs cover exactly the served rids
    assert set(ex.outputs) == set(served_rids(m))


def test_multi_engine_executor_requires_engine_per_hosted_model(
        serving_engines):
    ex = EngineContinuousExecutor(
        {"bloom-3b": serving_engines["bloom-3b"]}, seed=0)
    with pytest.raises(KeyError, match="no ServingEngine"):
        ex.bind(make_menv(2))
