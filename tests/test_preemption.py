"""Priority preemption: bit-exact spill/resume through the engine's
forced-prefix replay, and the runtime's preemption accounting.

The load-bearing property (DESIGN.md §2.4): a preempted-and-resumed
request's final output is BIT-IDENTICAL to the same request served
uninterrupted.  The engine gets there by replaying, not trusting, the
delivered prefix — the resumed row re-prefills its ORIGINAL prompt and
the decode loop forces the already-delivered tokens back out position
by position (``forced``/``n_forced``), so the prefix the user saw is
pinned exactly and the continuation re-derives from the same cache
trajectory.  Checked on both the slab and the paged-arena decode paths.
"""
from __future__ import annotations

import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv
from repro.core.request import Request, RequestGenerator
from repro.serving.kv_arena import KVArena
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   ContinuousRuntime,
                                   EngineContinuousExecutor)
from repro.serving.slo import SpillRecord

ENV = paper_env("bloom-3b", "W8A16")


@pytest.fixture(scope="module")
def eng():
    from repro.serving.engine import ServingEngine
    return ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                         s_max=16, n_max=8)


def _drive(eng, st, k=3):
    """Run a cohort to exhaustion; returns (state, out, lengths)."""
    while True:
        st = eng.generate_chunked(st, k)
        out, lengths, done, t = eng.poll_chunked(st)
        if eng.exhausted(lengths, done, st.caps_host, t):
            return st, out, lengths


def _arena(eng, paged):
    return KVArena.for_engines([eng], block_tokens=8) if paged else None


# -- engine level: the bit-exactness contract --------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
def test_preempted_resume_bit_identical(eng, paged):
    prompt = [3, 5, 7, 2]

    # reference: the request served alone, never interrupted
    st, out, lengths = _drive(eng, st := eng.start_chunked(
        [prompt], [8], arena=_arena(eng, paged)))
    ref = np.asarray(out[0][:lengths[0]]).copy()
    assert lengths[0] == 8
    if paged:
        eng.release_all(st)

    # interrupted: same prompt inside a busy cohort, evicted mid-flight
    st = eng.start_chunked([prompt, [1, 2], [9, 4, 6]], [8, 8, 8],
                           arena=_arena(eng, paged))
    st = eng.generate_chunked(st, 3)
    out, lengths, done, t = eng.poll_chunked(st)
    prefix = [int(x) for x in out[0][:lengths[0]]]
    assert 0 < len(prefix) < len(ref)
    # batched rows decode independently: the delivered prefix already
    # matches the solo reference
    assert np.array_equal(prefix, ref[:len(prefix)])
    st = eng.evict_slots(st, [0])
    st, _, _ = _drive(eng, st)          # survivors drain past the eviction
    if paged:
        eng.release_all(st)

    # resume: fresh cohort, ORIGINAL prompt, delivered prefix replayed
    st = eng.start_chunked([prompt], [8], arena=_arena(eng, paged),
                           prefixes=[prefix])
    st, out, lengths = _drive(eng, st)
    resumed = np.asarray(out[0][:lengths[0]])
    assert np.array_equal(resumed, ref), (resumed, ref)
    if paged:
        eng.release_all(st)


@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
def test_evict_slots_frees_rows_and_pages(eng, paged):
    arena = _arena(eng, paged)
    st = eng.start_chunked([[1, 2], [3, 4], [5, 6]], [8, 8, 8],
                           arena=arena)
    if paged:
        free_before = arena.free_pages
    st = eng.evict_slots(st, [0, 2])
    _, lengths, done, _ = eng.poll_chunked(st, with_tokens=False)
    assert done[0] and done[2] and not done[1]
    assert st.caps_host[0] == 0 and st.caps_host[2] == 0
    if paged:
        assert arena.free_pages > free_before     # leases returned
    # dead rows keep stepping as don't-care work; the cohort still drains
    st, _, lengths = _drive(eng, st)
    assert lengths[1] == 8
    if paged:
        eng.release_all(st)


# -- runtime level: preemption end-to-end on the real engine -----------------


def conserved(m):
    assert m.arrived == m.served + m.dropped + m.shed \
        + len(m.final_queue_rids) + len(m.in_flight_rids), \
        (m.arrived, m.served, m.dropped, m.shed,
         len(m.final_queue_rids), len(m.in_flight_rids))


def test_engine_runtime_preempts_and_resumes(eng):
    gen = RequestGenerator(rate=8, seed=3, lengths=(4, 8),
                           tau_range=(0.5, 6.0), priorities=(0, 1, 2))
    cexec = EngineContinuousExecutor(eng, seed=0, collect_tokens=True)
    rt = ContinuousRuntime(ENV, "dftsp", cexec, k=2, preemption=True,
                           max_preemptions=2, backoff_boundaries=1)
    m = rt.run(gen=gen, n_epochs=4, warmup_epochs=0)
    conserved(m)
    assert m.preempted > 0
    assert m.resumed > 0
    served = [rid for t in m.traces for rid in t.finished_rids]
    assert len(served) == len(set(served)) == m.served
    # every served row's tokens were collected exactly once
    assert sorted(cexec.outputs) == sorted(served)


def test_analytic_runtime_preempts_with_spill_accounting():
    gen = RequestGenerator(rate=30, seed=0, tau_range=(0.5, 6.0),
                           priorities=(0, 1, 2))
    rt = ContinuousRuntime(ENV, "dftsp",
                           AnalyticContinuousExecutor(capacity=4), k=64,
                           preemption=True)
    m = rt.run(gen=gen, n_epochs=6, warmup_epochs=0)
    conserved(m)
    assert m.preempted > 0
    # a resume is only counted when the preempted rid actually re-lands
    assert 0 <= m.resumed <= m.preempted + m.served


def _req(rid=0, s=4, n=8, tau=30.0, priority=0):
    return Request(rid=rid, s=s, n=n, tau=tau, a=0.5, h=1e-3,
                   arrival=0.0, priority=priority)


def test_engine_preempt_payload_reports_remaining(eng):
    """Regression: the engine preempt payload historically carried only
    (prompt, prefix), so the deadline gate re-judged a spilled request
    on its FULL n — a half-served long request looked hopeless even
    when its remaining half met the deadline.  Both payloads now carry
    ``remaining``."""
    cexec = EngineContinuousExecutor(eng, seed=0)
    cexec.bind(ENV)
    r = _req()
    cexec.place(None, r)
    cexec.step(ENV, 3)
    payload = cexec.preempt(None, r.rid)
    assert 0 < len(payload["prefix"]) < 8
    assert payload["remaining"] == 8 - len(payload["prefix"])


def test_hopeless_judges_spilled_requests_on_remaining_tokens():
    rt = ContinuousRuntime(ENV, "dftsp",
                           AnalyticContinuousExecutor(capacity=4), k=4,
                           deadline_gated=True)
    rt._tnow = 0.0
    dt = rt.T_E / rt.segments_per_epoch
    r = _req(n=64, tau=4.5 * dt)
    assert rt._hopeless(r, None)          # 16 segments from scratch
    rec = SpillRecord(request=r, payload={"remaining": 8})
    assert not rt._hopeless(r, rec)       # 2 segments left: feasible
    rec = SpillRecord(request=r, payload={"remaining": 60})
    assert rt._hopeless(r, rec)


# -- cross-pool preemption under shared-arena pressure (DESIGN.md §2.3/2.4) --


MENV = MultiLLMEnv.host({
    "bloom-3b": paper_env("bloom-3b", "W8A16"),
    "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
})


def _two_pool_cexec(**kw):
    from repro.serving.engine import ServingEngine
    ea = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=2,
                       s_max=16, n_max=8, eos_id=-1)
    eb = ServingEngine(reduced_cfg("bloom-7b1"), batch_capacity=2,
                       s_max=16, n_max=8, eos_id=-1)
    arena = KVArena.for_engines([ea, eb], block_tokens=8, shrink=0.5)
    return EngineContinuousExecutor({"bloom-3b": ea, "bloom-7b1": eb},
                                    seed=0, arena=arena, **kw), arena


def test_arena_blocked_flags_cross_pool_memory_pressure():
    """Regression: preemption historically searched victims only in the
    CANDIDATE's pool, but when the shared arena binds, any cohort's
    freed pages help — ``arena_blocked`` is the signal that widens the
    victim search, and evicting another pool's resident must actually
    unblock the admission."""
    cexec, arena = _two_pool_cexec()
    cexec.bind(MENV)
    residents = [_req(rid=10 + i) for i in range(2)]
    for r in residents:
        assert cexec.accepts("bloom-7b1", r)
        cexec.place("bloom-7b1", r)
    cexec.step(MENV, 1)
    rc = _req(rid=0, priority=1)
    assert cexec.free_slots("bloom-3b") > 0
    assert not cexec.accepts("bloom-3b", rc)      # page budget refuses
    assert cexec.arena_blocked("bloom-3b", rc)    # ...and says why
    # evicting the OTHER pool's resident returns its pages to the node
    payload = cexec.preempt("bloom-7b1", residents[0].rid)
    assert payload["remaining"] > 0
    assert cexec.accepts("bloom-3b", rc)
    assert not cexec.arena_blocked("bloom-3b", rc)


def test_cross_pool_preemption_run_conserves():
    cexec, _ = _two_pool_cexec(collect_tokens=True)

    def tagger(arrivals):
        for i, r in enumerate(arrivals):
            r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
        return arrivals

    rt = ContinuousRuntime(MENV, "multi-dftsp", cexec, k=2,
                           preemption=True, max_preemptions=2,
                           backoff_boundaries=1)
    m = rt.run(gen=RequestGenerator(rate=10, seed=3, lengths=(4, 8),
                                    tau_range=(0.5, 8.0),
                                    priorities=(0, 1, 2)),
               n_epochs=4, warmup_epochs=0, tag_arrivals=tagger)
    conserved(m)
    assert m.served > 0
    served = [rid for t in m.traces for rid in t.finished_rids]
    assert len(served) == len(set(served)) == m.served
    assert sorted(cexec.outputs) == sorted(served)


def test_preemption_respects_attempt_cap():
    """max_preemptions=0 pins every resident: nothing is ever evicted."""
    gen = RequestGenerator(rate=30, seed=0, tau_range=(0.5, 6.0),
                           priorities=(0, 1, 2))
    rt = ContinuousRuntime(ENV, "dftsp",
                           AnalyticContinuousExecutor(capacity=4), k=64,
                           preemption=True, max_preemptions=0)
    m = rt.run(gen=gen, n_epochs=6, warmup_epochs=0)
    conserved(m)
    assert m.preempted == 0 and m.resumed == 0
