"""Quantization as a scheduling decision: refactor-seam tests.

Golden equivalence: fixed-method policies must stay BIT-IDENTICAL to the
pre-refactor runtime (per-epoch selected rids + aggregate counters were
captured from the code base before ``quant`` became a decision variable).
Property: ``quant=auto`` can never serve a smaller batch than the best
single fixed method on the same queue, and dominates every fixed method
end-to-end on a mixed accuracy-requirement workload.
"""
from __future__ import annotations

import pytest

from repro.core import problem
from repro.core.dftsp import dftsp_schedule, dftsp_schedule_auto
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, multi_dftsp_assign, multi_feasible
from repro.core.policy import Decision, get_policy
from repro.core.quantization import (METHODS, candidate_methods, dominates,
                                     get_method, pareto_methods)
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

ENV = paper_env("bloom-3b", "W8A16")


def run(env, spec, rate=25, n_epochs=6, seed=11, gen=None):
    return EpochRuntime(env, spec, AnalyticExecutor()).run(
        rate=None if gen else rate, n_epochs=n_epochs, seed=seed, gen=gen)


# ---------------------------------------------------------------------------
# Golden equivalence with the pre-refactor runtime (captured at PR-2 base,
# commit 9ed7029: quant frozen in EdgeEnv, rate=25, n_epochs=6, seed=11).
# ---------------------------------------------------------------------------

GOLDEN = {
    ("bloom-3b", "W8A16", "dftsp"): dict(
        served=29, dropped=265, arrived=307, nodes=3761,
        rids=[[], [32, 37, 38, 40, 34, 35], [85, 86, 88, 90],
              [148, 138, 154, 141, 152, 142], [192, 191, 195],
              [238, 243, 240, 246, 235], [305, 301, 297, 304, 300]]),
    ("opt-13b", "W4A16-GPTQ", "dftsp"): dict(
        served=6, dropped=293, arrived=307, nodes=198,
        rids=[[], [40], [85, 86], [148], [], [246], [301]]),
    ("bloom-3b", "W8A16", "stb"): dict(
        served=9, dropped=281, arrived=307,
        rids=[[], [25, 29], [67], [116, 120], [183, 184], [223], [275]]),
    ("bloom-3b", "W8A16", "nob"): dict(
        served=1, dropped=288, arrived=307,
        rids=[[], [], [86], [], [], [], []]),
    ("bloom-3b", "W8A16", "greedy"): dict(
        served=20, dropped=271, arrived=307,
        rids=[[], [32, 29, 40, 38], [85, 80, 70], [148, 138, 120],
              [203, 193, 183], [238, 243, 240, 246, 235], [305, 278]]),
}


@pytest.mark.parametrize("model,quant,spec", sorted(k for k in GOLDEN))
def test_fixed_method_runs_bit_identical_to_pre_refactor(model, quant, spec):
    g = GOLDEN[(model, quant, spec)]
    m = run(paper_env(model, quant), spec)
    assert [t.selected_rids for t in m.traces] == g["rids"]
    assert (m.served, m.dropped, m.arrived) == \
        (g["served"], g["dropped"], g["arrived"])
    if "nodes" in g:
        assert m.nodes_visited == g["nodes"]


def test_multi_dftsp_bit_identical_to_pre_refactor():
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })

    def tagger(arrivals):
        for i, r in enumerate(arrivals):
            r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
        return arrivals

    m = EpochRuntime(menv, "multi-dftsp", AnalyticExecutor()).run(
        rate=40, n_epochs=4, seed=3, tag_arrivals=tagger)
    assert (m.served, m.dropped, m.arrived, m.nodes_visited) == \
        (23, 270, 309, 1559)
    assert [t.selected_rids for t in m.traces] == [
        [], [52, 62, 46, 64, 58, 61], [151, 137, 139, 123, 143, 152],
        [233, 231, 209, 237, 236], [306, 308, 302, 304, 294, 305]]


@pytest.mark.parametrize("seed", range(4))
def test_explicit_quant_equals_env_quant(seed):
    """dftsp parameterized by env's own method == the implicit default,
    decision by decision (the refactor seam is invisible)."""
    reqs = RequestGenerator(rate=40, seed=seed).within(0, 2.0)
    a, sa = dftsp_schedule(ENV, reqs)
    b, sb = dftsp_schedule(ENV, reqs, quant=ENV.quant)
    assert [r.rid for r in a] == [r.rid for r in b]
    assert (sa.nodes_visited, sa.z_solved) == (sb.nodes_visited, sb.z_solved)
    pol_env = run(ENV, "dftsp", rate=25, seed=seed)
    pol_fix = run(ENV, "dftsp:quant=W8A16", rate=25, seed=seed)
    assert [t.selected_rids for t in pol_env.traces] == \
        [t.selected_rids for t in pol_fix.traces]
    assert pol_env.served == pol_fix.served


# ---------------------------------------------------------------------------
# Method prefilter / Pareto pruning
# ---------------------------------------------------------------------------


def test_dominates_requires_all_axes():
    w16, w8a16 = get_method("W16A16"), get_method("W8A16")
    w8a8 = get_method("W8A8")
    # W8A16 is cheaper on alpha/beta but loses accuracy: no dominance
    assert not dominates(w8a16, w16, "bloom-3b")
    assert not dominates(w16, w8a16, "bloom-3b")
    assert not dominates(w8a8, w8a16, "bloom-3b")
    assert {m.name for m in pareto_methods(METHODS.values(), "bloom-3b")} \
        == set(METHODS)
    # a strictly-worse synthetic method IS dropped
    from repro.core.quantization import QuantMethod
    bad = QuantMethod("W8A16-bad", 8, 16, beta=0.9, dppl_default=0.9)
    front = pareto_methods(list(METHODS.values()) + [bad], "bloom-3b")
    assert {m.name for m in front} == set(METHODS)


def test_candidate_methods_accuracy_prefilter():
    # nobody tolerates dPPL >= 0.6 => the W4 methods drop out on bloom-3b
    cands = candidate_methods("bloom-3b", accuracies=[0.9])
    names = {m.name for m in cands}
    assert "W4A16-GPTQ" not in names and "W4A16-ZQL" not in names
    assert "W16A16" in names
    # fastest-first deterministic order
    betas = [m.beta for m in cands]
    assert betas == sorted(betas)
    # demand nobody can meet under any quantized model: only exact-dppl==0
    assert {m.name for m in candidate_methods("bloom-3b",
                                              accuracies=[1.0])} == {"W16A16"}


# ---------------------------------------------------------------------------
# quant=auto optimality properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_auto_never_smaller_than_best_fixed_same_queue(seed):
    """Schedule-level: the (z, method) descent's first hit is the max
    batch size over every method — auto >= each fixed method."""
    reqs = RequestGenerator(rate=50, seed=seed).within(0, 2.0)
    sel, method, _ = dftsp_schedule_auto(ENV, reqs)
    assert method.name in METHODS
    fixed = {name: len(dftsp_schedule(ENV, reqs, quant=q)[0])
             for name, q in METHODS.items()}
    assert len(sel) >= max(fixed.values())
    # and the chosen method itself achieves that size
    assert len(sel) == fixed[method.name]
    # the batch is feasible under the chosen method
    assert problem.feasible(ENV, sel, quant=method)


@pytest.mark.parametrize("seed", range(4))
def test_auto_throughput_dominates_every_fixed_method(seed):
    """End-to-end acceptance: on a mixed accuracy-requirement workload the
    adaptive policy's throughput >= every fixed METHODS deployment."""
    def served(spec):
        gen = RequestGenerator(rate=60, seed=seed, acc_range=(0.0, 1.0))
        return run(ENV, spec, n_epochs=10, seed=seed, gen=gen).served

    auto = served("dftsp:quant=auto")
    for name in METHODS:
        assert auto >= served(f"dftsp:quant={name}"), name


def test_auto_records_decided_methods_per_epoch():
    gen = RequestGenerator(rate=30, seed=0, acc_range=(0.9, 1.0))
    m = run(ENV, "dftsp:quant=auto", n_epochs=8, seed=0, gen=gen)
    assert sum(m.served_by_method.values()) == m.served
    assert len(m.served_by_method) >= 2          # strict pool forces a mix
    for t in m.traces:
        if t.selected_rids:
            assert set(t.quants.values()) <= set(METHODS)
        else:
            assert t.quants == {}


def test_auto_respects_accuracy_on_strict_requests():
    """A request demanding a > f(dPPL(W8A16)) can only be served at
    W16A16 — auto must select it rather than drop the request."""
    gen = RequestGenerator(rate=10, seed=1, acc_range=(0.96, 1.0))
    m = run(ENV, "dftsp:quant=auto", n_epochs=6, seed=1, gen=gen)
    assert m.served > 0
    assert set(m.served_by_method) == {"W16A16"}


def test_auto_validates_under_decided_method():
    """The policy oracle must judge the decision under the method it
    decided, not the env default (W16A16 batches of strict requests are
    infeasible under the env's W8A16)."""
    policy = get_policy("dftsp:quant=auto")
    gen = RequestGenerator(rate=20, seed=2, acc_range=(0.96, 1.0))
    queue = gen.within(0, 2.0)
    decision = policy.schedule(ENV, queue)
    assert decision.size > 0
    assert decision.quants[None].name == "W16A16"
    assert policy.validate(ENV, decision)
    # the same batch under the env default fails the accuracy constraint
    assert not problem.feasible(ENV, decision.selected)
    # and a tampered decision claiming the env method must be rejected
    tampered = Decision(batches=decision.batches, stats=decision.stats)
    assert not policy.validate(ENV, tampered)


# ---------------------------------------------------------------------------
# multi-LLM per-model method selection
# ---------------------------------------------------------------------------


def _menv():
    return MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })


def _tagged_pool(seed=0, rate=40, **kw):
    gen = RequestGenerator(rate=rate, seed=seed, **kw)
    reqs = gen.within(0, 2.0)
    for i, r in enumerate(reqs):
        r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
    return reqs


def test_multi_auto_assigns_per_model_and_stays_feasible():
    menv = _menv()
    batches, quants, stats = multi_dftsp_assign(menv, _tagged_pool(seed=4),
                                                quant="auto")
    assert set(quants) == set(menv.envs)
    assert stats.z_solved == sum(len(b) for b in batches.values())
    assert multi_feasible(menv, batches, quants=quants)


def test_multi_auto_never_below_fixed_default():
    for seed in range(3):
        pool = _tagged_pool(seed=seed, rate=50)
        menv = _menv()
        fixed, _, _ = multi_dftsp_assign(menv, pool)
        auto, _, _ = multi_dftsp_assign(menv, pool, quant="auto")
        assert sum(len(b) for b in auto.values()) >= \
            sum(len(b) for b in fixed.values()), seed


def test_multi_auto_through_runtime_records_quants():
    menv = _menv()

    def tagger(arrivals):
        for i, r in enumerate(arrivals):
            r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
        return arrivals

    m = EpochRuntime(menv, "multi-dftsp:quant=auto", AnalyticExecutor()).run(
        rate=40, n_epochs=4, seed=3, tag_arrivals=tagger)
    assert m.served > 0
    assert sum(m.served_by_method.values()) == m.served
    for t in m.traces:
        assert set(t.quants) <= set(menv.envs)
