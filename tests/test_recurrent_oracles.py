"""Chunked-scan kernels vs naive recurrent oracles: the mLSTM chunkwise
form and the Mamba2 SSD chunked form must match their O(T) step-by-step
references (the TPU adaptation's correctness proof)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import mamba2, xlstm


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.integers(0, 2 ** 31 - 1))
def test_mlstm_chunked_matches_reference(B, nh_pow, chunk_factor, seed):
    nh = 2 ** nh_pow
    dh, T = 8, 4 * chunk_factor * 2
    keys = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(keys[0], (B, T, nh, dh))
    k = jax.random.normal(keys[1], (B, T, nh, dh))
    v = jax.random.normal(keys[2], (B, T, nh, dh))
    ilog = jax.random.normal(keys[3], (B, T, nh))
    flog = jax.nn.log_sigmoid(jax.random.normal(keys[4], (B, T, nh)) + 2.0)
    h_c, st_c = xlstm.mlstm_chunked(q, k, v, ilog, flog,
                                    chunk=4 * chunk_factor)
    h_r, st_r = xlstm.mlstm_reference(q, k, v, ilog, flog)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c["C"] * np.exp(
        np.asarray(st_c["m"]))[..., None, None]),
        np.asarray(st_r["C"] * np.exp(np.asarray(st_r["m"]))[..., None, None]),
        rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_state_carry():
    """Two sequential chunked calls == one call over the concatenation."""
    B, T, nh, dh = 1, 16, 2, 8
    keys = jax.random.split(jax.random.key(3), 5)
    q = jax.random.normal(keys[0], (B, 2 * T, nh, dh))
    k = jax.random.normal(keys[1], (B, 2 * T, nh, dh))
    v = jax.random.normal(keys[2], (B, 2 * T, nh, dh))
    ilog = jax.random.normal(keys[3], (B, 2 * T, nh))
    flog = jax.nn.log_sigmoid(jax.random.normal(keys[4], (B, 2 * T, nh)))
    full, _ = xlstm.mlstm_chunked(q, k, v, ilog, flog, chunk=8)
    h1, st1 = xlstm.mlstm_chunked(q[:, :T], k[:, :T], v[:, :T],
                                  ilog[:, :T], flog[:, :T], chunk=8)
    h2, _ = xlstm.mlstm_chunked(q[:, T:], k[:, T:], v[:, T:],
                                ilog[:, T:], flog[:, T:], chunk=8, state=st1)
    got = jnp.concatenate([h1, h2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([4, 8]), st.integers(0, 2 ** 31 - 1))
def test_ssd_chunked_matches_reference(B, chunk, seed):
    T, H, P, N = 16, 2, 4, 4
    keys = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(keys[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(keys[2], (H,)) * 0.5)
    Bm = jax.random.normal(keys[3], (B, T, N))
    Cm = jax.random.normal(jax.random.key(seed + 1), (B, T, N))
    y_c, st_c = mamba2.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_r, st_r = mamba2.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                               rtol=2e-3, atol=2e-3)


def test_chunked_causal_attention_matches_direct():
    """The XLA-level blocked attention == direct masked attention."""
    from repro.models import common
    B, S, nh, nkv, dh = 2, 32, 8, 4, 16
    q = jax.random.normal(jax.random.key(0), (B, S, nh, dh))
    k = jax.random.normal(jax.random.key(1), (B, S, nkv, dh))
    v = jax.random.normal(jax.random.key(2), (B, S, nkv, dh))
    for window in (0, 8):
        direct = common.gqa_attention(q, k, v,
                                      common.causal_mask(S, S, window))
        blocked = common.chunked_causal_attention(q, k, v, window, chunk=8)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(direct),
                                   rtol=2e-5, atol=2e-5)
