"""DFTSP correctness: optimality (vs exhaustive subset enumeration),
brute-force equivalence (Table III pair), and P1 feasibility invariants
— hypothesis property tests over random request pools.
"""
from __future__ import annotations

import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import problem, schedulers
from repro.core.dftsp import dftsp_schedule
from repro.core.environment import paper_env
from repro.core.request import Request

ENV = paper_env("bloom-3b", "W8A16")


def make_request(rid, s, n, tau, a, h):
    return Request(rid=rid, s=s, n=n, tau=tau, a=a, h=h)


request_st = st.builds(
    make_request,
    rid=st.integers(0, 10_000),
    s=st.sampled_from([128, 256, 512]),
    n=st.sampled_from([128, 256, 512]),
    tau=st.floats(0.5, 2.0),
    a=st.floats(0.0, 1.0),
    h=st.floats(0.005, 0.08),
)


def pools(max_n=10):
    return st.lists(request_st, min_size=0, max_size=max_n,
                    unique_by=lambda r: r.rid)


@settings(max_examples=40, deadline=None)
@given(pools())
def test_dftsp_batch_is_feasible(reqs):
    sel, _ = dftsp_schedule(ENV, reqs)
    assert problem.feasible(ENV, sel)


@settings(max_examples=30, deadline=None)
@given(pools(max_n=9))
def test_dftsp_is_optimal_vs_exhaustive(reqs):
    """|DFTSP batch| == max feasible subset size (the paper's optimality
    claim, checked against literal subset enumeration)."""
    sel, _ = dftsp_schedule(ENV, reqs)
    best, _ = schedulers.exhaustive(ENV, reqs)
    assert len(sel) == len(best)


@settings(max_examples=20, deadline=None)
@given(pools(max_n=10))
def test_pruning_preserves_optimality(reqs):
    """Brute-force tree search (no pruning/order) finds the same z with
    at least as many visited nodes (Table III's comparison)."""
    fast, s_fast = dftsp_schedule(ENV, reqs)
    slow, s_slow = dftsp_schedule(ENV, reqs, prune=False, order_desc=False,
                                  fast_z_bound=False)
    assert len(fast) == len(slow)
    assert s_slow.nodes_visited >= s_fast.nodes_visited


@settings(max_examples=25, deadline=None)
@given(pools(), st.floats(0.1, 1.0))
def test_monotone_in_memory(reqs, shrink):
    """Shrinking the memory budget can never increase the batch size."""
    sel_full, _ = dftsp_schedule(ENV, reqs)
    env_small = ENV.with_(M=ENV.M * shrink)
    sel_small, _ = dftsp_schedule(env_small, reqs)
    assert len(sel_small) <= len(sel_full)


@settings(max_examples=25, deadline=None)
@given(pools())
def test_accuracy_filter(reqs):
    """No selected request may exceed the quantized model's accuracy."""
    env = paper_env("bloom-3b", "W4A16-GPTQ")   # dPPL 0.75 => f ~ 0.47
    sel, _ = dftsp_schedule(env, reqs)
    f = math.exp(-env.quant.delta_ppl("bloom-3b"))
    assert all(r.a <= f + 1e-9 for r in sel)


def test_empty_pool():
    sel, stats = dftsp_schedule(ENV, [])
    assert sel == [] and stats.z_solved == 0


def test_single_feasible_request():
    r = make_request(1, 128, 128, 2.0, 0.1, 0.05)
    sel, _ = dftsp_schedule(ENV, [r])
    assert len(sel) == 1


def test_deadline_impossible_request_rejected():
    r = make_request(1, 512, 512, 0.01, 0.1, 0.05)   # 10ms deadline
    sel, _ = dftsp_schedule(ENV, [r])
    assert sel == []
