"""Shared fixtures: reduced model configs for CPU-scale testing.

NOTE: no XLA_FLAGS here — smoke tests and benches must see the host's
real device count (the 512-device override belongs ONLY to the dry-run).
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.config import (EncDecConfig, MoEConfig, ModelConfig, get_arch)

# Reduced variants of each assigned family (2 layers, d_model <= 512,
# <= 4 experts) used by the per-arch smoke tests.
REDUCTIONS = {
    "xlstm-1.3b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       vocab=512),
    "mistral-large-123b": dict(n_layers=2, d_model=256, n_heads=8,
                               n_kv_heads=2, d_ff=512, vocab=512),
    "internvl2-26b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab=512),
    "olmo-1b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                    d_ff=256, vocab=512),
    "whisper-tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                         d_ff=256, vocab=512),
    "mixtral-8x22b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=512),
    "deepseek-coder-33b": dict(n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=2, d_ff=256, vocab=512),
    "zamba2-7b": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab=512),
    "granite-moe-1b-a400m": dict(n_layers=2, d_model=128, n_heads=4,
                                 n_kv_heads=2, d_ff=64, vocab=512),
    "qwen3-1.7b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512),
    "bloom-3b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                     d_ff=512, vocab=512),
    "bloom-7b1": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=512, vocab=512),
    "opt-13b": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                    d_ff=512, vocab=512),
}


def reduced_cfg(arch_id: str) -> ModelConfig:
    cfg = get_arch(arch_id).scaled(**REDUCTIONS[arch_id])
    if cfg.is_moe and cfg.moe.n_experts > 4:
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(n_experts=4, top_k=min(cfg.moe.top_k, 2)))
    if cfg.family == "audio":
        cfg = dataclasses.replace(
            cfg, encdec=EncDecConfig(n_enc_layers=2, n_audio_frames=32))
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=16)
    return cfg


@pytest.fixture
def rng_key():
    import jax
    return jax.random.key(0)
