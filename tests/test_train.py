"""Training substrate: AdamW math, LR schedule, loss descent, checkpoints."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.train import Trainer, checkpoint
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   global_norm, lr_schedule)


def test_adamw_first_step_matches_hand_computation():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=1, total_steps=10)
    p = {"w": jnp.ones((2, 2))}
    g = {"w": jnp.full((2, 2), 0.5)}
    st = adamw_init(p)
    new_p, st2, _ = adamw_update(cfg, g, st, p)
    # bias-corrected first step = lr * g/|g| = lr (elementwise sign-ish)
    lr0 = 0.1 * 1 / 1          # warmup: step1 => full lr
    expect = 1.0 - lr0 * (0.5 / (np.sqrt(0.5 ** 2) + 1e-8))
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.full((2, 2), expect), rtol=1e-5)


def test_grad_clip_scales():
    cfg = AdamWConfig(grad_clip=1.0)
    g = {"w": jnp.full((4,), 10.0)}
    assert float(global_norm(g)) == pytest.approx(20.0)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_loss_decreases():
    cfg = reduced_cfg("olmo-1b")
    tr = Trainer(cfg, batch=8, seq=64)
    _, hist = tr.run(25, log_every=5, log=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_moments_are_f32_for_bf16_params():
    p = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    st = adamw_init(p)
    assert st.mu["w"].dtype == jnp.float32


def test_checkpoint_roundtrip():
    cfg = reduced_cfg("qwen3-1.7b")
    tr = Trainer(cfg, batch=2, seq=16)
    state, _ = tr.run(2, log_every=10, log=lambda s: None)
    path = tempfile.mktemp(suffix=".npz")
    try:
        checkpoint.save(path, state.params)
        p2 = checkpoint.restore(path, state.params)
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(p2)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-2, atol=1e-2)   # bf16 roundtrips via f32
    finally:
        if os.path.exists(path):
            os.unlink(path)


def test_checkpoint_shape_mismatch_rejected():
    path = tempfile.mktemp(suffix=".npz")
    try:
        checkpoint.save(path, {"w": jnp.ones((2, 2))})
        with pytest.raises(AssertionError):
            checkpoint.restore(path, {"w": jnp.ones((3, 3))})
    finally:
        if os.path.exists(path):
            os.unlink(path)
