"""Serving engine + end-to-end DFTSP-driven serving.

Includes the decode-loop contract tests: the fused device-resident
``lax.while_loop`` path (``generate``) must match the legacy host-driven
loop (``generate_reference``) bit for bit, with exactly ONE host→device
and ONE device→host transfer per batch.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.request import RequestGenerator
from repro.serving.engine import ServingEngine
from repro.serving.runtime import EngineExecutor, EpochRuntime


def assert_same_generation(a, b):
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.lengths, b.lengths)
    assert a.batch == b.batch


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("bloom-3b")
    return ServingEngine(cfg, batch_capacity=4, s_max=32, n_max=8)


def test_generate_shapes(engine):
    res = engine.generate([[1, 2, 3], [4, 5, 6, 7]], n_tokens=[5, 8])
    assert res.tokens.shape == (2, 8)
    assert res.lengths[0] <= 5 and res.lengths[1] <= 8
    assert res.batch == 2


def test_generate_respects_caps(engine):
    res = engine.generate([[1, 2, 3]], n_tokens=[3])
    assert res.lengths[0] <= 3
    assert np.all(res.tokens[0, 3:] == 0)


def test_generate_deterministic(engine):
    a = engine.generate([[5, 6, 7]], n_tokens=[6])
    b = engine.generate([[5, 6, 7]], n_tokens=[6])
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_quantized_engine_runs():
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=2, s_max=16, n_max=4,
                        quant_bits=8)
    res = eng.generate([[1, 2, 3]], n_tokens=[4])
    assert res.tokens.shape == (1, 4)


def test_pad_prompts_right_aligned(engine):
    out = engine.pad_prompts([[7, 8, 9]])
    assert out.shape == (4, 32)
    assert list(out[0, -3:]) == [7, 8, 9]
    assert out[0, :-3].sum() == 0


def test_engine_runtime_end_to_end(engine):
    env = paper_env("bloom-3b", "W8A16")
    trace = EpochRuntime(env, "dftsp", EngineExecutor(engine, seed=0)).run(
        rate=5, n_epochs=3, seed=0, warmup_epochs=0)
    assert trace.epochs == 3
    assert trace.served >= 0
    assert len(trace.batches) == 3
    # real data plane => per-epoch wall-clock is measured and aggregated
    assert trace.wall_s > 0
    assert trace.wall_s == pytest.approx(
        sum(t.wall_s for t in trace.traces if t.counted))
    if trace.generated_tokens:
        assert trace.tokens_per_s > 0
        assert any(t.tokens_per_s > 0 for t in trace.traces)


# -- fused decode-loop contract ---------------------------------------------


def test_fused_matches_reference_edge_cases(engine):
    """cap=0 rows, pad-token prompts and padding-only rows (fewer prompts
    than batch_capacity) all decode bit-identically to the legacy loop."""
    prompts = [[1, 2, 3], [0, 0], [7]]       # slot 4 stays padding-only
    caps = [5, 0, 8]
    a = engine.generate(prompts, n_tokens=caps)
    b = engine.generate_reference(prompts, n_tokens=caps)
    assert_same_generation(a, b)
    assert a.lengths[1] == 0                 # cap=0 row emits nothing
    assert np.all(a.tokens[1] == 0)


def test_fused_matches_reference_empty_batch(engine):
    a = engine.generate([], n_tokens=[])
    b = engine.generate_reference([], n_tokens=[])
    assert_same_generation(a, b)
    assert a.tokens.shape == (0, engine.n_max)


@pytest.mark.parametrize("bits", [0, 8, 4])
def test_fused_matches_reference_all_precisions(engine, bits):
    """Equivalence holds for every bit-width the engine caches — the
    quant_bits override routes both paths through the same weight tree."""
    prompts = [[5, 6, 7], [1, 2], [9, 9, 9, 9]]
    a = engine.generate(prompts, n_tokens=[8, 3, 6], quant_bits=bits)
    b = engine.generate_reference(prompts, n_tokens=[8, 3, 6],
                                  quant_bits=bits)
    assert_same_generation(a, b)
    assert a.lengths.max() >= 1


def test_fused_immediate_eos(engine):
    """A row whose FIRST sampled token is EOS emits exactly one token in
    both paths (the EOS itself, as the legacy loop always did)."""
    ref = engine.generate_reference([[9, 8, 7]], n_tokens=[6])
    tok0 = int(ref.tokens[0, 0])
    eng2 = ServingEngine(engine.cfg, params=engine._raw_params,
                         batch_capacity=4, s_max=32, n_max=8, eos_id=tok0)
    a = eng2.generate([[9, 8, 7]], n_tokens=[6])
    b = eng2.generate_reference([[9, 8, 7]], n_tokens=[6])
    assert_same_generation(a, b)
    assert a.lengths[0] == 1
    assert a.tokens[0, 0] == tok0
    assert np.all(a.tokens[0, 1:] == 0)


def test_fused_generate_single_host_sync(engine, monkeypatch):
    """The one-transfer-per-batch contract, probed at the real transfer
    points: fused generate makes exactly ONE device_put (prompts + caps)
    and ONE device_get (tokens + lengths); the reference loop pays one
    blocking device_get per decoded token on top."""
    counts = {"get": 0, "put": 0}
    real_get, real_put = jax.device_get, jax.device_put

    def counting_get(x):
        counts["get"] += 1
        return real_get(x)

    def counting_put(x):
        counts["put"] += 1
        return real_put(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    monkeypatch.setattr(jax, "device_put", counting_put)

    engine.generate([[1, 2, 3], [4, 5, 6]], n_tokens=[5, 5])
    assert counts == {"get": 1, "put": 1}

    counts.update(get=0, put=0)
    ref = engine.generate_reference([[1, 2, 3], [4, 5, 6]], n_tokens=[5, 5])
    # first token + one argmax sync per decode step
    assert counts["get"] == 1 + int(ref.lengths.max())
    assert counts["get"] > 1


def test_params_for_caches_each_precision():
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=2, s_max=16, n_max=4)
    p16 = eng.params_for(16)
    assert p16 is eng.params_for(0)          # 16 == full precision
    assert eng.params_for(8) is eng.params_for(8)      # quantized once
    assert set(eng._params_cache) == {0, 8}
    r8 = eng.generate([[1, 2, 3]], n_tokens=[4], quant_bits=8)
    r16 = eng.generate([[1, 2, 3]], n_tokens=[4], quant_bits=16)
    assert r8.tokens.shape == r16.tokens.shape == (1, 4)
    assert eng.precisions_served == {0, 8}


def test_engine_serves_decided_precisions_in_one_run():
    """quant=auto on a strict-accuracy workload mixes W16A16 and W8A16
    epochs; the engine must execute both precisions via the weight
    cache (acceptance criterion for quantization-as-control)."""
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=8, s_max=16, n_max=4)
    env = paper_env("bloom-3b", "W8A16")
    gen = RequestGenerator(rate=30, seed=0, acc_range=(0.9, 1.0))
    m = EpochRuntime(env, "dftsp:quant=auto",
                     EngineExecutor(eng, seed=0)).run(
        n_epochs=8, seed=0, gen=gen, warmup_epochs=0)
    assert m.served > 0
    assert len(m.served_by_method) >= 2          # adaptive method mix
    assert len(eng.precisions_served) >= 2       # distinct weight bits
    assert set(eng.precisions_served) <= set(eng._params_cache)
