"""Serving engine + end-to-end DFTSP-driven serving."""
from __future__ import annotations

import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.request import RequestGenerator
from repro.serving.engine import ServingEngine
from repro.serving.runtime import EngineExecutor, EpochRuntime


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("bloom-3b")
    return ServingEngine(cfg, batch_capacity=4, s_max=32, n_max=8)


def test_generate_shapes(engine):
    res = engine.generate([[1, 2, 3], [4, 5, 6, 7]], n_tokens=[5, 8])
    assert res.tokens.shape == (2, 8)
    assert res.lengths[0] <= 5 and res.lengths[1] <= 8
    assert res.batch == 2


def test_generate_respects_caps(engine):
    res = engine.generate([[1, 2, 3]], n_tokens=[3])
    assert res.lengths[0] <= 3
    assert np.all(res.tokens[0, 3:] == 0)


def test_generate_deterministic(engine):
    a = engine.generate([[5, 6, 7]], n_tokens=[6])
    b = engine.generate([[5, 6, 7]], n_tokens=[6])
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_quantized_engine_runs():
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=2, s_max=16, n_max=4,
                        quant_bits=8)
    res = eng.generate([[1, 2, 3]], n_tokens=[4])
    assert res.tokens.shape == (1, 4)


def test_pad_prompts_right_aligned(engine):
    out = engine.pad_prompts([[7, 8, 9]])
    assert out.shape == (4, 32)
    assert list(out[0, -3:]) == [7, 8, 9]
    assert out[0, :-3].sum() == 0


def test_engine_runtime_end_to_end(engine):
    env = paper_env("bloom-3b", "W8A16")
    trace = EpochRuntime(env, "dftsp", EngineExecutor(engine, seed=0)).run(
        rate=5, n_epochs=3, seed=0, warmup_epochs=0)
    assert trace.epochs == 3
    assert trace.served >= 0
    assert len(trace.batches) == 3


def test_params_for_caches_each_precision():
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=2, s_max=16, n_max=4)
    p16 = eng.params_for(16)
    assert p16 is eng.params_for(0)          # 16 == full precision
    assert eng.params_for(8) is eng.params_for(8)      # quantized once
    assert set(eng._params_cache) == {0, 8}
    r8 = eng.generate([[1, 2, 3]], n_tokens=[4], quant_bits=8)
    r16 = eng.generate([[1, 2, 3]], n_tokens=[4], quant_bits=16)
    assert r8.tokens.shape == r16.tokens.shape == (1, 4)
    assert eng.precisions_served == {0, 8}


def test_engine_serves_decided_precisions_in_one_run():
    """quant=auto on a strict-accuracy workload mixes W16A16 and W8A16
    epochs; the engine must execute both precisions via the weight
    cache (acceptance criterion for quantization-as-control)."""
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=8, s_max=16, n_max=4)
    env = paper_env("bloom-3b", "W8A16")
    gen = RequestGenerator(rate=30, seed=0, acc_range=(0.9, 1.0))
    m = EpochRuntime(env, "dftsp:quant=auto",
                     EngineExecutor(eng, seed=0)).run(
        n_epochs=8, seed=0, gen=gen, warmup_epochs=0)
    assert m.served > 0
    assert len(m.served_by_method) >= 2          # adaptive method mix
    assert len(eng.precisions_served) >= 2       # distinct weight bits
    assert set(eng.precisions_served) <= set(eng._params_cache)
