"""Serving engine + end-to-end DFTSP-driven serving."""
from __future__ import annotations

import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.serving.engine import ServingEngine
from repro.serving.simulator import serve_epochs


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("bloom-3b")
    return ServingEngine(cfg, batch_capacity=4, s_max=32, n_max=8)


def test_generate_shapes(engine):
    res = engine.generate([[1, 2, 3], [4, 5, 6, 7]], n_tokens=[5, 8])
    assert res.tokens.shape == (2, 8)
    assert res.lengths[0] <= 5 and res.lengths[1] <= 8
    assert res.batch == 2


def test_generate_respects_caps(engine):
    res = engine.generate([[1, 2, 3]], n_tokens=[3])
    assert res.lengths[0] <= 3
    assert np.all(res.tokens[0, 3:] == 0)


def test_generate_deterministic(engine):
    a = engine.generate([[5, 6, 7]], n_tokens=[6])
    b = engine.generate([[5, 6, 7]], n_tokens=[6])
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_quantized_engine_runs():
    cfg = reduced_cfg("bloom-3b")
    eng = ServingEngine(cfg, batch_capacity=2, s_max=16, n_max=4,
                        quant_bits=8)
    res = eng.generate([[1, 2, 3]], n_tokens=[4])
    assert res.tokens.shape == (1, 4)


def test_pad_prompts_right_aligned(engine):
    out = engine.pad_prompts([[7, 8, 9]])
    assert out.shape == (4, 32)
    assert list(out[0, -3:]) == [7, 8, 9]
    assert out[0, :-3].sum() == 0


def test_serve_epochs_end_to_end(engine):
    env = paper_env("bloom-3b", "W8A16")
    trace = serve_epochs(env, engine, "dftsp", rate=5, n_epochs=3, seed=0)
    assert trace.epochs == 3
    assert trace.served >= 0
    assert len(trace.batches) == 3
