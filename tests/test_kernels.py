"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU; TPU is the deploy target)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant.ptq import quantize

QMM_SHAPES = [(128, 256, 128), (64, 512, 384), (4, 300, 200),
              (1, 128, 128), (130, 260, 76)]


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("shape", QMM_SHAPES)
def test_quant_matmul_vs_ref(bits, shape):
    M, K, N = shape
    x = jax.random.normal(jax.random.key(1), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.key(2), (K, N), jnp.float32)
    t = quantize(w, bits)
    got = ops.quant_matmul(x, t.q, t.scale.reshape(-1), bits)
    want = ref.quant_matmul_ref(x, t.q, t.scale.reshape(-1), bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype):
    x = jax.random.normal(jax.random.key(1), (32, 256), dtype)
    w = jax.random.normal(jax.random.key(2), (256, 128), jnp.float32)
    t = quantize(w, 8)
    got = ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8)
    assert got.dtype == dtype
    want = ref.quant_matmul_ref(x, t.q, t.scale.reshape(-1), 8)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2)


def test_quant_matmul_batched_lead():
    x = jax.random.normal(jax.random.key(1), (2, 8, 256))
    w = jax.random.normal(jax.random.key(2), (256, 64))
    t = quantize(w, 8)
    got = ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8)
    assert got.shape == (2, 8, 64)


FD_CASES = [
    # (B, nh, nkv, dh, W, nv)
    (2, 8, 2, 64, 1024, 700),
    (1, 4, 4, 128, 512, 512),
    (3, 16, 8, 80, 256, 1),
    (2, 12, 4, 96, 384, 200),
    (1, 8, 1, 128, 2048, 1024),
]


@pytest.mark.parametrize("case", FD_CASES)
def test_flash_decode_vs_ref(case):
    B, nh, nkv, dh, W, nv = case
    q = jax.random.normal(jax.random.key(1), (B, nh, dh), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, W, nkv, dh), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, W, nkv, dh), jnp.float32)
    got = ops.flash_decode(q, k, v, nv)
    want = ref.flash_decode_ref(q, k, v, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_per_batch_validity():
    q = jax.random.normal(jax.random.key(1), (3, 8, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (3, 512, 4, 64), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (3, 512, 4, 64), jnp.float32)
    nv = jnp.array([100, 512, 3])
    got = ops.flash_decode(q, k, v, nv)
    want = ref.flash_decode_ref(q, k, v, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_bf16():
    q = jax.random.normal(jax.random.key(1), (2, 8, 128), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(2), (2, 256, 2, 128), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(3), (2, 256, 2, 128), jnp.bfloat16)
    got = ops.flash_decode(q, k, v, 200)
    want = ref.flash_decode_ref(q, k, v, 200)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_decode_matches_xla_gqa_attention():
    """The kernel must agree with the model's own decode attention math."""
    from repro.models import common
    B, nh, nkv, dh, W = 2, 8, 4, 64, 256
    q = jax.random.normal(jax.random.key(1), (B, 1, nh, dh))
    k = jax.random.normal(jax.random.key(2), (B, W, nkv, dh))
    v = jax.random.normal(jax.random.key(3), (B, W, nkv, dh))
    n_valid = 100
    mask = (jnp.arange(W) < n_valid)[None, None, None, None, :]
    want = common.gqa_attention(q, k, v, mask)[:, 0]
    got = ops.flash_decode(q[:, 0], k, v, n_valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
