"""Property tests for the OFDMA comm model + epoch simulation invariants."""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import comm
from repro.core.environment import dbm_to_watt, paper_env
from repro.core.request import BITS_PER_TOKEN, Request, RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

ENV = paper_env("bloom-3b", "W8A16")


def simulate(env, policy, rate, n_epochs=30, seed=0):
    return EpochRuntime(env, policy, AnalyticExecutor()).run(
        rate=rate, n_epochs=n_epochs, seed=seed)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([128, 256, 512]), st.floats(0.003, 0.1))
def test_rho_min_is_exactly_sufficient(s, h):
    """At rho = rho_min the prompt uploads in exactly T_U."""
    r = Request(0, s, 128, 1.0, 0.0, h)
    rho = comm.rho_min_up(ENV, r)
    rate = comm.rate_up(ENV, r, rho)
    assert rate * ENV.T_U == pytest.approx(s * BITS_PER_TOKEN, rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(st.floats(0.003, 0.05), st.floats(1.01, 5.0))
def test_better_channel_needs_less_bandwidth(h, gain):
    r1 = Request(0, 256, 128, 1.0, 0.0, h)
    r2 = Request(1, 256, 128, 1.0, 0.0, h * gain)
    assert comm.rho_min_up(ENV, r2) < comm.rho_min_up(ENV, r1)


def test_dbm_conversion():
    assert dbm_to_watt(0.0) == pytest.approx(1e-3)
    assert dbm_to_watt(30.0) == pytest.approx(1.0)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100), st.sampled_from([5.0, 25.0, 60.0]))
def test_epoch_accounting_invariants(seed, rate):
    res = simulate(ENV, "dftsp", rate, n_epochs=6, seed=seed)
    assert res.served >= 0 and res.dropped >= 0
    # every served/dropped request arrived (within queue carryover slack)
    assert res.served + res.dropped <= res.arrived + 4 * rate
    assert len(res.batch_sizes) == 6
    assert res.served == sum(res.batch_sizes)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 50))
def test_generator_reproducible(seed):
    a = RequestGenerator(rate=20, seed=seed).within(0, 2.0)
    b = RequestGenerator(rate=20, seed=seed).within(0, 2.0)
    assert [(r.s, r.n, r.tau, r.h) for r in a] == \
        [(r.s, r.n, r.tau, r.h) for r in b]


def test_request_marginals_match_paper():
    """§IV: lengths in {128,256,512}, tau in [0.5,2], a in [0,1]."""
    reqs = RequestGenerator(rate=500, seed=0).within(0, 2.0)
    assert len(reqs) > 500
    assert {r.s for r in reqs} <= {128, 256, 512}
    assert {r.n for r in reqs} <= {128, 256, 512}
    assert all(0.5 <= r.tau <= 2.0 for r in reqs)
    assert all(0.0 <= r.a <= 1.0 for r in reqs)
    assert all(r.h > 0 for r in reqs)
