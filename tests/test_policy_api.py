"""SchedulerPolicy / Executor / EpochRuntime API (the unified runtime).

Covers: registry round-trip (spec -> policy -> spec), validate() parity
with the historical ``nob_feasible`` / ``problem.feasible`` oracles on
randomized batches, the memoized StB batch size, the Request.model_id
field, capacity clamping with drop accounting, and an AnalyticExecutor vs
EngineExecutor smoke test showing identical scheduling decisions for the
same seed.
"""
from __future__ import annotations

import dataclasses
import random

import pytest

from conftest import reduced_cfg
from repro.core import problem, schedulers
from repro.core.environment import paper_env
from repro.core.metrics import EpochMetrics
from repro.core.multi import MultiLLMEnv, multi_feasible, tag
from repro.core.policy import (CallablePolicy, Decision, SchedulerPolicy,
                               as_policy, available, get_policy)
from repro.core.request import Request, RequestGenerator
from repro.serving.runtime import (AnalyticExecutor, EngineExecutor,
                                   EpochRuntime)

ENV = paper_env("bloom-3b", "W8A16")

CANONICAL_SPECS = [
    "dftsp", "stb", "nob", "greedy", "brute_force", "multi-dftsp",
    "dftsp:d_sweep=false", "dftsp:fast_z_bound=false,prune=false",
    "multi-dftsp:order=name", "dftsp:quant=auto", "dftsp:quant=W4A16-GPTQ",
    "multi-dftsp:quant=auto", "multi-dftsp:order=name,quant=auto",
]


# -- registry ---------------------------------------------------------------


@pytest.mark.parametrize("spec", CANONICAL_SPECS)
def test_registry_roundtrip(spec):
    policy = get_policy(spec)
    assert policy.spec == spec
    assert get_policy(policy.spec).spec == spec


def test_registry_lists_all_core_policies():
    assert {"dftsp", "stb", "nob", "greedy", "brute_force",
            "multi-dftsp"} <= set(available())


def test_param_coercion():
    p = get_policy("dftsp:prune=false,d_sweep=true")
    assert p.prune is False and p.d_sweep is True
    assert get_policy("multi-dftsp:order=load").order == "load"


def test_unknown_policy_and_bad_params_raise():
    with pytest.raises(KeyError):
        get_policy("nonexistent")
    with pytest.raises(TypeError):
        get_policy("dftsp:bogus_param=1")
    with pytest.raises(ValueError):
        get_policy("multi-dftsp:order=bogus")


def test_as_policy_coercions():
    assert isinstance(as_policy("dftsp"), SchedulerPolicy)
    p = as_policy(get_policy("stb"))
    assert as_policy(p) is p
    # known legacy callables map (by identity) to their registered class,
    # keeping e.g. NoB's per-unit oracle
    assert as_policy(schedulers.no_batching).spec == "nob"
    assert as_policy(schedulers.dftsp).spec == "dftsp"
    custom = as_policy(lambda env, reqs: ([], None))
    assert isinstance(custom, CallablePolicy)


# -- validate() parity with the historical oracles --------------------------


def _random_batches(seed, n_batches=25):
    gen = RequestGenerator(rate=40, seed=seed)
    pool = gen.within(0, 2.0)
    rng = random.Random(seed)
    for _ in range(n_batches):
        k = rng.randint(0, min(len(pool), 12))
        yield rng.sample(pool, k)


def test_validate_parity_with_p1_oracle():
    policy = get_policy("dftsp")
    for batch in _random_batches(seed=11):
        decision = Decision.single(batch)
        assert policy.validate(ENV, decision) == \
            problem.feasible(ENV, batch)


def test_validate_parity_with_nob_oracle():
    policy = get_policy("nob")
    for batch in _random_batches(seed=12):
        decision = Decision.single(batch)
        assert policy.validate(ENV, decision) == \
            schedulers.nob_feasible(ENV, batch)


def test_multi_policy_validate_matches_oracle():
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })
    gen = RequestGenerator(rate=40, seed=3)
    reqs = gen.within(0, 2.0)
    half = len(reqs) // 2
    pool = tag(reqs[:half], "bloom-3b") + tag(reqs[half:], "bloom-7b1")
    policy = get_policy("multi-dftsp")
    decision = policy.schedule(menv, pool)
    assert decision.size == decision.stats.z_solved
    assert policy.validate(menv, decision)
    assert multi_feasible(menv, decision.batches)
    # an overfull joint schedule must be rejected
    bloated = Decision(batches={"bloom-3b": list(reqs)})
    for r in reqs:
        r.model_id = "bloom-3b"
    assert not policy.validate(menv, bloated)
    # an unhosted-model key must not short-circuit validation of the rest
    bloated.batches = {"ghost": [], **bloated.batches}
    assert not policy.validate(menv, bloated)
    ghost_req = tag([reqs[0]], "ghost")
    assert not multi_feasible(menv, {"ghost": ghost_req})


def test_host_rejects_mismatched_epoch_grids():
    with pytest.raises(ValueError):
        MultiLLMEnv.host({
            "bloom-3b": paper_env("bloom-3b", "W8A16", T_E=2.0),
            "bloom-7b1": paper_env("bloom-7b1", "W8A16", T_E=1.0),
        })


# -- satellite: memoized StB batch size -------------------------------------


def test_static_batch_size_memoized_and_surfaced():
    schedulers._STATIC_BATCH_CACHE.clear()
    B = schedulers.static_batch_size(ENV)
    assert len(schedulers._STATIC_BATCH_CACHE) == 1
    assert schedulers.static_batch_size(ENV) == B
    assert len(schedulers._STATIC_BATCH_CACHE) == 1    # cache hit, no growth
    assert get_policy("stb").batch_size(ENV) == B
    # a different env derives (and caches) its own size
    env2 = paper_env("bloom-7b1", "W8A16")
    B2 = schedulers.static_batch_size(env2)
    assert len(schedulers._STATIC_BATCH_CACHE) == 2
    assert B2 <= B      # bigger model can never admit a larger worst case


# -- satellite: Request.model_id is a real field ----------------------------


def test_model_id_is_a_dataclass_field():
    names = {f.name for f in dataclasses.fields(Request)}
    assert "model_id" in names
    r = Request(0, 128, 128, 1.0, 0.0, 0.05)
    assert r.model_id is None
    tag([r], "bloom-3b")            # thin compat wrapper
    assert r.model_id == "bloom-3b"


# -- runtime: metrics units, decisions --------------------------------------


def test_runtime_returns_unified_metrics():
    res = EpochRuntime(ENV, "dftsp", AnalyticExecutor()).run(
        rate=10, n_epochs=5, seed=7)
    assert isinstance(res, EpochMetrics)
    assert res.throughput == pytest.approx(
        res.served / (5 * ENV.T_E))                      # requests/second
    assert len(res.batch_sizes) == 5
    assert len(res.traces) == 6                          # + warmup epoch
    assert not res.traces[0].counted
    # fixed-method runs attribute every served request to the env method
    assert set(res.served_by_method) <= {ENV.quant.name}


def test_runtime_deterministic_across_runs():
    policy = get_policy("dftsp")
    a = EpochRuntime(ENV, "dftsp", AnalyticExecutor()).run(
        rate=10, n_epochs=5, seed=7)
    b = EpochRuntime(ENV, policy, AnalyticExecutor()).run(
        rate=10, n_epochs=5, seed=7)
    assert (a.served, a.dropped, a.arrived, a.nodes_visited) == \
        (b.served, b.dropped, b.arrived, b.nodes_visited)
    assert [t.selected_rids for t in a.traces] == \
        [t.selected_rids for t in b.traces]
    assert [t.quants for t in a.traces] == [t.quants for t in b.traces]


def test_multi_llm_through_runtime():
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })

    def tagger(arrivals):
        for i, r in enumerate(arrivals):
            r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
        return arrivals

    m = EpochRuntime(menv, "multi-dftsp", AnalyticExecutor()).run(
        rate=40, n_epochs=4, seed=0, tag_arrivals=tagger)
    assert m.served > 0
    assert len(m.batch_sizes) == 4
    assert m.served == sum(m.batch_sizes)


def test_untargeted_requests_drop_on_multi_env():
    menv = MultiLLMEnv.host({"bloom-3b": paper_env("bloom-3b", "W8A16")})
    m = EpochRuntime(menv, "multi-dftsp", AnalyticExecutor()).run(
        rate=10, n_epochs=3, seed=0)       # nobody tags => nothing viable
    assert m.served == 0
    assert m.dropped == m.arrived


# -- executors: equivalence + capacity clamping (real JAX engine) -----------


@pytest.fixture(scope="module")
def small_engine_cfg():
    return reduced_cfg("bloom-3b")


def test_analytic_vs_engine_same_decisions(small_engine_cfg):
    from repro.serving.engine import ServingEngine
    policy = get_policy("dftsp")
    analytic = EpochRuntime(ENV, policy, AnalyticExecutor()).run(
        rate=2, n_epochs=3, seed=1, warmup_epochs=0)
    engine = ServingEngine(small_engine_cfg, batch_capacity=16,
                           s_max=16, n_max=4)
    engined = EpochRuntime(ENV, policy, EngineExecutor(engine, seed=1)).run(
        rate=2, n_epochs=3, seed=1, warmup_epochs=0)
    assert [t.selected_rids for t in analytic.traces] == \
        [t.selected_rids for t in engined.traces]
    assert analytic.served == engined.served
    assert engined.generated_tokens > 0
    assert analytic.generated_tokens == 0


def test_engine_capacity_clamp_counts_drops(small_engine_cfg):
    from repro.serving.engine import ServingEngine
    engine = ServingEngine(small_engine_cfg, batch_capacity=1,
                           s_max=16, n_max=4)
    m = EpochRuntime(ENV, "dftsp", EngineExecutor(engine, seed=0)).run(
        rate=6, n_epochs=3, seed=0, warmup_epochs=0)
    assert all(b <= 1 for b in m.batch_sizes)       # clamped to capacity
    assert m.truncated > 0                          # spill is counted
    assert m.served == sum(m.batch_sizes)
