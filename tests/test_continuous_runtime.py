"""Continuous-batching runtime: conservation, admission control, and the
``InfeasibleDecisionError`` contract.

The load-bearing property (hypothesis, over random arrival streams and
EVERY registered policy spec): the queue lifecycle conserves requests —
``arrived == served + dropped + len(final_queue)`` — and no rid is ever
served twice, for BOTH the epoch-boundary runtime and the continuous
path.  Deterministic pytest variants cover the same invariant without
hypothesis installed (CI installs it; see requirements-test.txt).
"""
from __future__ import annotations

import types

import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv
from repro.core.policy import (Decision, InfeasibleDecisionError,
                               SchedulerPolicy, available)
from repro.core.request import ReplayGenerator, RequestGenerator
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   AnalyticExecutor, ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime)

ENV = paper_env("bloom-3b", "W8A16")
MENV = MultiLLMEnv.host({
    "bloom-3b": paper_env("bloom-3b", "W8A16"),
    "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
})
SINGLE_SPECS = sorted(s for s in available() if s != "multi-dftsp")


def _tagger(arrivals):
    for i, r in enumerate(arrivals):
        r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
    return arrivals


def _spec_env(spec):
    multi = spec.startswith("multi-dftsp")
    return (MENV if multi else ENV), (_tagger if multi else None)


def assert_conserved(m):
    assert m.arrived == m.served + m.dropped + len(m.final_queue_rids), \
        (m.arrived, m.served, m.dropped, len(m.final_queue_rids))


def served_rids(m):
    """rids served by either runtime: the epoch loop serves at selection,
    the continuous loop at completion (finished_rids)."""
    continuous = any(t.segments for t in m.traces)
    pick = (lambda t: t.finished_rids) if continuous \
        else (lambda t: t.selected_rids)
    return [rid for t in m.traces if t.counted for rid in pick(t)]


# -- deterministic conservation (runs without hypothesis) --------------------


@pytest.mark.parametrize("spec", available())
def test_epoch_runtime_conserves_requests(spec):
    env, tagger = _spec_env(spec)
    m = EpochRuntime(env, spec, AnalyticExecutor()).run(
        rate=4, n_epochs=5, seed=7, warmup_epochs=0, tag_arrivals=tagger)
    assert_conserved(m)
    rids = served_rids(m)
    assert len(rids) == len(set(rids)) == m.served


@pytest.mark.parametrize("spec", available())
def test_continuous_runtime_conserves_requests(spec):
    env, tagger = _spec_env(spec)
    m = ContinuousRuntime(env, spec, AnalyticContinuousExecutor(capacity=4),
                          k=64).run(rate=4, n_epochs=5, seed=7,
                                    warmup_epochs=0, tag_arrivals=tagger)
    assert_conserved(m)
    rids = served_rids(m)
    assert len(rids) == len(set(rids)) == m.served
    admitted = [rid for t in m.traces for rid in t.selected_rids]
    assert sorted(admitted) == sorted(rids)      # every admission finishes


# -- the hypothesis property over random streams and every policy ------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(spec=st.sampled_from(available()),
           seed=st.integers(0, 2**16),
           rate=st.floats(0.5, 5.0),
           capacity=st.integers(1, 8),
           k=st.sampled_from([1, 32, 64, 256, 512]))
    def test_conservation_property_both_runtimes(spec, seed, rate,
                                                 capacity, k):
        env, tagger = _spec_env(spec)
        epoch = EpochRuntime(env, spec, AnalyticExecutor()).run(
            rate=rate, n_epochs=4, seed=seed, warmup_epochs=0,
            tag_arrivals=tagger)
        cont = ContinuousRuntime(
            env, spec, AnalyticContinuousExecutor(capacity=capacity),
            k=k).run(rate=rate, n_epochs=4, seed=seed, warmup_epochs=0,
                     tag_arrivals=tagger)
        for m in (epoch, cont):
            assert_conserved(m)
            rids = served_rids(m)
            assert len(rids) == len(set(rids)) == m.served


# -- continuous structure: segments, occupancy, mid-epoch admission ----------


def test_segment_grid_reduces_to_epoch_protocol_at_k_max():
    ex = AnalyticContinuousExecutor(capacity=4, tokens_per_epoch_=512)
    assert ContinuousRuntime(ENV, "dftsp", ex, k=512).segments_per_epoch == 1
    assert ContinuousRuntime(ENV, "dftsp", ex, k=64).segments_per_epoch == 8
    assert ContinuousRuntime(ENV, "dftsp", ex, k=1000,
                             ).segments_per_epoch == 1


def test_continuous_records_segments_and_occupancy():
    m = ContinuousRuntime(ENV, "dftsp", AnalyticContinuousExecutor(capacity=2),
                          k=128).run(rate=5, n_epochs=4, seed=3,
                                     warmup_epochs=0)
    for t in m.traces:
        assert len(t.occupancy) == t.segments
        assert all(0.0 <= o <= 1.0 for o in t.occupancy)
    assert m.segments == sum(t.segments for t in m.traces if t.counted)
    assert 0.0 < m.mean_occupancy <= 1.0


def test_mid_epoch_admission_happens_under_backlog():
    """With a small pool and a hot queue, slots freed by finishing rows
    are refilled at interior segment boundaries — the capacity the
    epoch protocol leaves on the table."""
    m = ContinuousRuntime(ENV, "dftsp", AnalyticContinuousExecutor(capacity=2),
                          k=128).run(rate=8, n_epochs=4, seed=0,
                                     warmup_epochs=0)
    assert m.admitted_mid_epoch > 0
    assert m.admitted_mid_epoch == sum(t.admitted_mid_epoch
                                       for t in m.traces if t.counted)
    # epoch-boundary runs never admit mid-epoch
    e = EpochRuntime(ENV, "dftsp", AnalyticExecutor()).run(
        rate=8, n_epochs=4, seed=0, warmup_epochs=0)
    assert e.admitted_mid_epoch == 0 and e.segments == 0


def test_admission_is_gated_by_policy_oracle():
    """A policy whose oracle rejects everything admits nothing on the
    continuous path (validate() IS the admission contract)."""

    class RejectAll(SchedulerPolicy):
        name = "reject-all-stub"

        def schedule(self, env, queue):
            return Decision.single([])

        def validate(self, env, decision):
            return not decision.selected

    m = ContinuousRuntime(ENV, RejectAll(),
                          AnalyticContinuousExecutor(capacity=4),
                          k=128).run(rate=5, n_epochs=3, seed=0,
                                     warmup_epochs=0)
    assert m.served == 0
    assert_conserved(m)


# -- engine-backed continuous path (real data plane) -------------------------


@pytest.fixture(scope="module")
def small_engine():
    from repro.serving.engine import ServingEngine
    cfg = reduced_cfg("bloom-3b")
    return ServingEngine(cfg, batch_capacity=4, s_max=16, n_max=8)


def test_engine_continuous_end_to_end(small_engine):
    gen = RequestGenerator(rate=6, seed=0, lengths=(2, 4, 8))
    m = ContinuousRuntime(ENV, "dftsp",
                          EngineContinuousExecutor(small_engine, seed=0),
                          k=2).run(gen=gen, n_epochs=3, seed=0,
                                   warmup_epochs=0)
    assert_conserved(m)
    assert m.served > 0
    assert m.generated_tokens > 0
    assert m.wall_s > 0 and m.tokens_per_s > 0
    rids = served_rids(m)
    assert len(rids) == len(set(rids)) == m.served


def test_engine_continuous_beats_epoch_on_backlogged_queue(small_engine):
    """The acceptance direction (full sweep in
    benchmarks/continuous_vs_epoch.py): identical frozen traffic, same
    policy — continuous admission serves at least as many requests as
    the epoch-boundary baseline."""
    from repro.serving.engine import ServingEngine
    # cut at the epoch protocol's last admission boundary so both paths
    # see identical offered load (3 of the 4 epochs carry arrivals)
    base = ReplayGenerator.poisson(6.0, 3 * ENV.T_E, seed=1,
                                   lengths=(2, 4, 8))
    epoch = EpochRuntime(ENV, "dftsp",
                         EngineExecutor(small_engine, seed=0)).run(
        gen=ReplayGenerator(base.requests), n_epochs=4, seed=1,
        warmup_epochs=0)
    eng2 = ServingEngine(small_engine.cfg, params=small_engine._raw_params,
                         batch_capacity=4, s_max=16, n_max=8)
    cont = ContinuousRuntime(ENV, "dftsp",
                             EngineContinuousExecutor(eng2, seed=0),
                             k=2).run(gen=ReplayGenerator(base.requests),
                                      n_epochs=4, seed=1, warmup_epochs=0)
    assert_conserved(cont)
    assert cont.served >= epoch.served
    assert cont.admitted_mid_epoch > 0


def test_engine_override_precision_labelled_honestly(small_engine):
    """A quant_bits override is an engine-level choice, not a scheduled
    METHODS decision — served_by_method must say so instead of claiming
    the env's deployed method ran."""
    gen = RequestGenerator(rate=6, seed=0, lengths=(2, 4, 8))
    m = ContinuousRuntime(ENV, "dftsp",
                          EngineContinuousExecutor(small_engine, seed=0,
                                                   quant_bits=8),
                          k=2).run(gen=gen, n_epochs=3, seed=0,
                                   warmup_epochs=0)
    assert m.served > 0
    assert set(m.served_by_method) == {"weight_bits=8"}
    assert 8 in small_engine.precisions_served


# -- InfeasibleDecisionError: the schedulers-must-not-cheat contract ---------


class CheatingPolicy(SchedulerPolicy):
    """Schedules the whole queue but its own oracle rejects any
    non-empty batch — the runtime's re-check must catch it."""

    name = "cheating-stub"

    def schedule(self, env, queue):
        return Decision.single(list(queue))

    def validate(self, env, decision):
        return not decision.selected


def test_runtime_raises_on_cheating_policy():
    with pytest.raises(InfeasibleDecisionError, match="infeasible"):
        EpochRuntime(ENV, CheatingPolicy(), AnalyticExecutor()).run(
            rate=20, n_epochs=2, seed=0, warmup_epochs=0)


def test_engine_admit_raises_when_clamped_batch_fails_oracle():
    """Capacity clamping re-validates against the policy's oracle and
    raises (not asserts) on failure — the contract survives python -O."""
    gen = RequestGenerator(rate=10, seed=0)
    reqs = gen.within(0, 1.0)
    assert len(reqs) >= 3
    fake_engine = types.SimpleNamespace(batch_capacity=1)
    ex = EngineExecutor(fake_engine)
    with pytest.raises(InfeasibleDecisionError, match="clamped"):
        ex.admit(ENV, CheatingPolicy(), Decision.single(reqs[:3]))
    # no spill => the oracle is not consulted, nothing raises
    dec, spilled = ex.admit(ENV, CheatingPolicy(), Decision.single(reqs[:1]))
    assert spilled == [] and dec.size == 1


def test_infeasible_error_is_a_runtime_error():
    assert issubclass(InfeasibleDecisionError, RuntimeError)
    assert not issubclass(InfeasibleDecisionError, AssertionError)
