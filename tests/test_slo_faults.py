"""Overload-hardened serving (§2.4): fault injection, conservation,
quarantine, graceful degradation, the typed drain stall, and the SLO
queue machinery.

The extended conservation equation is the backbone invariant here::

    arrived == served + dropped + shed + queued + in_flight

and it must hold under EVERY seeded :class:`FaultPlan` — the hypothesis
sweep drives both runtimes, every registered policy spec, and random
transient-fault schedules through it.  The other bit-level contract:
injection never perturbs the data plane, so a transient-only plan
leaves every served row's tokens identical to the fault-free run.
"""
from __future__ import annotations

import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv
from repro.core.policy import DrainStallError, available
from repro.core.request import BurstyGenerator, Request, RequestGenerator
from repro.serving.faults import FaultPlan, FaultyExecutor
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   AnalyticExecutor, ContinuousRuntime,
                                   EpochRuntime, still_viable)
from repro.serving.slo import (DegradationController, edf_order,
                               pick_victim)

ENV = paper_env("bloom-3b", "W8A16")
MENV = MultiLLMEnv.host({
    "bloom-3b": paper_env("bloom-3b", "W8A16"),
    "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
})


def _tagger(arrivals):
    for i, r in enumerate(arrivals):
        r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
    return arrivals


def _spec_env(spec):
    multi = spec.startswith("multi-dftsp")
    return (MENV if multi else ENV), (_tagger if multi else None)


def conserved(m):
    assert m.arrived == m.served + m.dropped + m.shed \
        + len(m.final_queue_rids) + len(m.in_flight_rids), \
        (m.arrived, m.served, m.dropped, m.shed,
         len(m.final_queue_rids), len(m.in_flight_rids))


def _req(rid=0, s=64, n=64, tau=2.0, arrival=0.0, priority=0, a=0.5):
    return Request(rid=rid, s=s, n=n, tau=tau, a=a, h=1e-3,
                   arrival=arrival, priority=priority)


# -- deterministic fault-plan conservation (runs without hypothesis) ---------


@pytest.mark.parametrize("runtime", ["epoch", "continuous"])
def test_conservation_under_transient_faults(runtime):
    plan = FaultPlan(seed=3, p_transient=0.25)
    if runtime == "epoch":
        rt = EpochRuntime(ENV, "dftsp",
                          FaultyExecutor(AnalyticExecutor(), plan))
    else:
        rt = ContinuousRuntime(
            ENV, "dftsp",
            FaultyExecutor(AnalyticContinuousExecutor(capacity=4), plan),
            k=64)
    m = rt.run(rate=6, n_epochs=5, seed=7, warmup_epochs=0)
    conserved(m)
    assert m.faults_injected > 0
    assert m.retried > 0


def test_faulty_executor_injection_is_seeded():
    runs = []
    for _ in range(2):
        fx = FaultyExecutor(AnalyticContinuousExecutor(capacity=4),
                            FaultPlan(seed=9, p_transient=0.3))
        m = ContinuousRuntime(ENV, "dftsp", fx, k=64).run(
            rate=6, n_epochs=4, seed=1, warmup_epochs=0)
        runs.append((m.faults_injected, m.served, m.dropped,
                     tuple(t.faults for t in m.traces)))
    assert runs[0] == runs[1]


def test_quarantine_after_consecutive_failures():
    """A pool failing every step (retry budget exhausted each boundary)
    is quarantined: evacuated with shed accounting, never re-admitted,
    and the run still terminates with conservation intact."""
    fx = FaultyExecutor(AnalyticContinuousExecutor(capacity=4),
                        FaultPlan(seed=0, p_transient=1.0))
    rt = ContinuousRuntime(ENV, "dftsp", fx, k=64, retry_limit=0,
                           quarantine_after=3)
    m = rt.run(rate=6, n_epochs=4, seed=7, warmup_epochs=0)
    conserved(m)
    assert m.quarantined == ["None"]      # the single-model pool's key
    assert m.served == 0                  # every step faulted


def test_max_transient_caps_injection():
    fx = FaultyExecutor(AnalyticContinuousExecutor(capacity=4),
                        FaultPlan(seed=2, p_transient=1.0,
                                  max_transient=5))
    m = ContinuousRuntime(ENV, "dftsp", fx, k=64,
                          quarantine_after=100).run(
        rate=6, n_epochs=4, seed=7, warmup_epochs=0)
    conserved(m)
    assert m.faults_injected == 5
    assert m.quarantined == []            # streak never reaches the bar
    assert m.served > 0                   # the plan runs dry, service resumes


def test_arena_squeeze_defers_admission_without_crashing():
    """An arena_holds window shrinks the free list mid-run; per-block
    admission control must defer, not crash, and hand the pages back."""
    from repro.serving.engine import ServingEngine
    from repro.serving.kv_arena import KVArena
    from repro.serving.runtime import EngineContinuousExecutor
    eng = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                        s_max=16, n_max=8)
    arena = KVArena.for_engines([eng], block_tokens=8)
    fx = FaultyExecutor(
        EngineContinuousExecutor(eng, seed=0, arena=arena),
        FaultPlan(seed=0, arena_holds=((2, 6, arena.n_pages),)))
    m = ContinuousRuntime(ENV, "dftsp", fx, k=2).run(
        gen=RequestGenerator(rate=6, seed=0, lengths=(4, 8)),
        n_epochs=3, warmup_epochs=0)
    conserved(m)
    assert m.served > 0
    assert not fx._held                   # every hold window closed


def test_transient_faults_leave_served_tokens_bit_identical():
    """The injection contract end-to-end on the real engine: a
    transient-only plan (faults raised BEFORE the step mutates state,
    absorbed by in-boundary retries) must leave every served row's
    collected tokens bit-identical to the fault-free run."""
    from repro.serving.engine import ServingEngine
    from repro.serving.runtime import EngineContinuousExecutor
    eng = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                        s_max=16, n_max=8)
    outs = []
    for plan in (None, FaultPlan(seed=5, p_transient=0.2,
                                 max_transient=30)):
        cexec = EngineContinuousExecutor(eng, seed=0, collect_tokens=True)
        ex = cexec if plan is None else FaultyExecutor(cexec, plan)
        m = ContinuousRuntime(ENV, "dftsp", ex, k=2).run(
            gen=RequestGenerator(rate=6, seed=0, lengths=(4, 8)),
            n_epochs=3, warmup_epochs=0)
        conserved(m)
        if plan is not None:
            assert m.faults_injected > 0
        outs.append(dict(cexec.outputs))
    assert sorted(outs[0]) == sorted(outs[1])
    for rid in outs[0]:
        assert np.array_equal(outs[0][rid], outs[1][rid]), rid


# -- DrainStallError: the typed stall contract -------------------------------


class StuckExecutor(AnalyticContinuousExecutor):
    """Residents never finish: the drain can only stall."""

    def step(self, env, k):
        return [], 1.0


def test_drain_stall_raises_typed_error_with_partial_metrics():
    rt = ContinuousRuntime(ENV, "dftsp", StuckExecutor(capacity=4),
                           k=64, drain_limit=10)
    with pytest.raises(DrainStallError) as ei:
        rt.run(rate=6, n_epochs=2, seed=0, warmup_epochs=0)
    e = ei.value
    assert isinstance(e, RuntimeError)     # callers catching the old
                                           # bare RuntimeError still work
    m = e.metrics
    assert m is not None
    assert e.resident_rids == m.in_flight_rids and m.in_flight_rids
    conserved(m)                           # partial metrics stay coherent
    assert m.served == 0 and m.arrived > 0


# -- SLO queue machinery: EDF order, victims, degradation hysteresis ---------


def test_edf_order_is_priority_major_deadline_minor():
    q = [_req(rid=0, tau=5.0, priority=0),
         _req(rid=1, tau=1.0, priority=0),
         _req(rid=2, tau=9.0, priority=2),
         _req(rid=3, tau=0.5, priority=1)]
    assert [r.rid for r in edf_order(q)] == [2, 3, 1, 0]


def test_pick_victim_only_trades_looser_for_tighter():
    res = [_req(rid=0, tau=1.0, priority=1), _req(rid=1, tau=4.0,
                                                  priority=1)]
    # same class, earlier deadline: evicts the LATEST-deadline resident
    v = pick_victim(res, _req(rid=2, tau=2.0, priority=1))
    assert v.rid == 1
    # equal requests never evict each other (no livelock)
    assert pick_victim(res, _req(rid=3, tau=4.0, priority=1)) is None
    # higher class beats regardless of deadline; lowest class goes first
    res = [_req(rid=0, tau=1.0, priority=0), _req(rid=1, tau=0.2,
                                                  priority=1)]
    assert pick_victim(res, _req(rid=4, tau=9.0, priority=2)).rid == 0


def test_degradation_hysteresis_needs_patience_both_ways():
    c = DegradationController(queue_high=10, queue_low=2, patience=2)
    assert not c.observe(50)              # one pressured boundary: no flip
    assert c.observe(50)                  # second: degraded
    c.record_finish(True)                 # degraded-era recovery evidence
    assert c.observe(0)                   # one relaxed boundary: still on
    assert not c.observe(0)               # second: recovered


def test_degradation_exit_requires_degraded_era_finishes():
    """Regression: entering degraded mode clears the attainment window,
    and the empty window (``att is None``) used to satisfy the relaxed
    condition — the controller could declare recovery after ``patience``
    idle boundaries during which NOTHING finished.  Exit now demands at
    least ``min_samples`` degraded-era finishes as evidence."""
    c = DegradationController(queue_high=10, queue_low=2, patience=2)
    c.record_finish(False)                # pre-degraded backlog history
    assert not c.observe(50)
    assert c.observe(50)                  # entered; window cleared
    assert c.recent_attainment is None
    for _ in range(6):                    # relaxed queue, zero finishes:
        assert c.observe(0)               # ...must stay degraded forever
    c.record_finish(True)                 # first degraded-era finish
    assert c.observe(0)                   # patience counts from HERE
    assert not c.observe(0)               # evidence + patience: recovered
    # min_samples > 1 demands that much evidence before the streak counts
    c2 = DegradationController(queue_high=10, queue_low=2, patience=1,
                               min_samples=2, degraded=True)
    c2.record_finish(True)
    assert c2.observe(0)                  # one finish < min_samples
    c2.record_finish(True)
    assert not c2.observe(0)              # two finishes: exit


def test_rising_edge_requant_skips_serving_inert_planes():
    """The analytic plane emits ``k`` tokens per segment REGARDLESS of
    method (``requant_effective`` False), so flipping its live cohorts
    at the rising edge would change nothing the plane delivers while
    loosening the oracle's admission latency bound — pure pricing
    optimism that only perturbs the tail.  The runtime must skip the
    flip there; the real-engine positive case is
    ``test_requant_flips_engine_cohort_midflight``."""
    assert AnalyticContinuousExecutor(capacity=4).requant_effective \
        is False
    rt = ContinuousRuntime(ENV, "dftsp:quant=W16A16",
                           AnalyticContinuousExecutor(capacity=4), k=64,
                           degradation=DegradationController(
                               queue_high=2, queue_low=0, patience=2))
    m = rt.run(gen=RequestGenerator(rate=30, seed=0), n_epochs=4,
               warmup_epochs=0)
    conserved(m)
    # cohorts STARTING while degraded may still serve the degraded
    # method (that selection is per-cohort-start, not a live flip);
    # only the mid-flight requant must not have happened
    assert m.requanted == 0


def test_requant_flips_engine_cohort_midflight():
    """Mid-flight requant on the real engine: rows that finished before
    the rising edge served at the cohort's original method, rows after
    it at the degraded one — same cohort, two precisions in
    ``served_by_method``, conservation intact."""
    from repro.serving.engine import ServingEngine
    from repro.serving.runtime import EngineContinuousExecutor
    eng = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                        s_max=16, n_max=8, eos_id=-1)
    cexec = EngineContinuousExecutor(eng, seed=0, collect_tokens=True)
    rt = ContinuousRuntime(ENV, "dftsp:quant=W16A16", cexec, k=2,
                           degradation=DegradationController(
                               queue_high=4, queue_low=0, patience=2))
    m = rt.run(gen=RequestGenerator(rate=10, seed=0, lengths=(4, 8)),
               n_epochs=3, warmup_epochs=0)
    conserved(m)
    assert m.requanted >= 1
    assert m.served_by_method.get("W16A16", 0) > 0
    assert m.served_by_method.get("W8A8", 0) > 0
    assert sum(m.served_by_method.values()) == m.served


def test_degradation_sheds_only_below_priority_floor():
    c = DegradationController(shed_below_priority=1, degraded=True)
    q = [_req(rid=0, priority=0), _req(rid=1, priority=1),
         _req(rid=2, priority=2)]
    assert [r.rid for r in c.shed_candidates(q)] == [0]
    c.degraded = False
    assert c.shed_candidates(q) == []


# -- BurstyGenerator: freeze-and-replay determinism --------------------------


def test_bursty_generator_is_frozen_and_deterministic():
    kw = dict(base_rate=8.0, horizon=10.0, seed=4, period=5.0, depth=0.5,
              bursts=((2.0, 3.0, 2.0),), priorities=(0, 1, 2))
    a, b = BurstyGenerator(**kw), BurstyGenerator(**kw)
    assert len(a.requests) > 0
    for ra, rb in zip(a.requests, b.requests):
        assert (ra.rid, ra.s, ra.n, ra.tau, ra.a, ra.h, ra.arrival,
                ra.priority) == (rb.rid, rb.s, rb.n, rb.tau, rb.a, rb.h,
                                 rb.arrival, rb.priority)
    # within() replays COPIES of the frozen stream: any slicing grid
    # reassembles the identical stream, and mutating a slice (the
    # runtimes age t_w in place) never corrupts the master copy
    fine = [r for t in np.arange(0.0, 10.0, 0.5)
            for r in a.within(float(t), float(t) + 0.5)]
    assert [r.rid for r in fine] == [r.rid for r in a.requests]
    fine[0].t_w = 99.0
    assert a.requests[0].t_w != 99.0


# -- hypothesis properties (CI installs hypothesis; local runs skip) ---------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(spec=st.sampled_from(available()),
           runtime=st.sampled_from(["epoch", "continuous"]),
           fault_seed=st.integers(0, 2**16),
           p=st.floats(0.0, 0.5),
           preemption=st.booleans())
    def test_conservation_under_fault_plans_property(spec, runtime,
                                                     fault_seed, p,
                                                     preemption):
        env, tagger = _spec_env(spec)
        plan = FaultPlan(seed=fault_seed, p_transient=p)
        if runtime == "epoch":
            rt = EpochRuntime(env, spec,
                              FaultyExecutor(AnalyticExecutor(), plan))
        else:
            rt = ContinuousRuntime(
                env, spec,
                FaultyExecutor(AnalyticContinuousExecutor(capacity=4),
                               plan),
                k=64, preemption=preemption,
                degradation=DegradationController(
                    queue_high=8, queue_low=2, shed_below_priority=1))
        m = rt.run(gen=RequestGenerator(rate=4, seed=11,
                                        priorities=(0, 1, 2)),
                   n_epochs=4, warmup_epochs=0, tag_arrivals=tagger)
        conserved(m)
        rids = [rid for t in m.traces
                for rid in (t.finished_rids if any(tt.segments
                                                   for tt in m.traces)
                            else t.selected_rids)]
        assert len(rids) == len(set(rids))

    @settings(max_examples=50, deadline=None)
    @given(s=st.integers(1, 2048), n=st.integers(1, 2048),
           tau=st.floats(0.01, 50.0), arrival=st.floats(0.0, 50.0),
           t1=st.floats(0.0, 100.0), dt=st.floats(0.0, 100.0))
    def test_still_viable_is_monotone_in_now(s, n, tau, arrival, t1, dt):
        """Aging can only hurt: once a queued request stops being
        viable it never becomes viable again, so _age_and_drop's
        drop decision is stable under any boundary grid."""
        r = _req(s=s, n=n, tau=tau, arrival=arrival)
        if still_viable(ENV, r, t1 + dt):
            assert still_viable(ENV, r, t1)

    @settings(max_examples=50, deadline=None)
    @given(s=st.integers(1, 2048), n=st.integers(1, 2048),
           tau=st.floats(0.01, 50.0), slack=st.floats(0.0, 10.0))
    def test_age_and_drop_keeps_lone_compute_viable_requests(s, n, tau,
                                                             slack):
        """A request whose lone-compute bound (comm + solo prefill +
        solo decode) still meets its deadline is NEVER dropped —
        the drop heuristic is an optimistic lower bound by contract."""
        rt = ContinuousRuntime(ENV, "dftsp",
                               AnalyticContinuousExecutor(capacity=4),
                               k=64)
        now = float(slack)
        r = _req(s=s, n=n, tau=tau, arrival=0.0)
        kept, dropped = rt._age_and_drop([r], now)
        cm = ENV.cost_model()
        lone = ENV.quant.beta * (cm.prefill_flops(r.s, 1)
                                 + cm.decode_flops(r.s, [r.n])) / ENV.C
        meets = now + ENV.T_U + lone + ENV.T_D <= r.tau
        if meets:
            assert kept == [r] and dropped == 0
