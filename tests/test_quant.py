"""PTQ substrate: roundtrip error bounds, int4 packing inverse, pytree
quantization invariants — hypothesis property tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.quant import (dequantize, pack_int4, quantize, quantize_tree,
                         tree_bytes, unpack_int4)
from repro.quant.ptq import INT4_MAX, INT8_MAX, dequantize_tree


shapes = st.tuples(st.integers(1, 6), st.integers(2, 65),
                   st.integers(1, 40))


@settings(max_examples=30, deadline=None)
@given(shapes, st.sampled_from([4, 8]), st.integers(0, 2 ** 31 - 1))
def test_roundtrip_error_bound(shape, bits, seed):
    """|w - dq(q(w))| <= scale/2 elementwise (symmetric RTN guarantee)."""
    w = jax.random.normal(jax.random.key(seed), shape)
    t = quantize(w, bits)
    wd = dequantize(t)
    qmax = INT4_MAX if bits == 4 else INT8_MAX
    bound = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / qmax * 0.5 + 1e-7
    assert bool(jnp.all(jnp.abs(wd - w) <= bound + 1e-6))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 31), st.integers(1, 33), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_inverse(rows2, cols, seed):
    q = jax.random.randint(jax.random.key(seed), (rows2 * 2, cols), -8, 8,
                           jnp.int8)
    assert bool(jnp.all(unpack_int4(pack_int4(q)) == q))


def test_quantize_preserves_leading_axes():
    w = jax.random.normal(jax.random.key(0), (3, 4, 32, 16))
    for bits, rows in ((8, 32), (4, 16)):
        t = quantize(w, bits)
        assert t.q.shape == (3, 4, rows, 16)
        assert t.scale.shape == (3, 4, 1, 16)
        assert dequantize(t).shape == w.shape


def test_quantize_tree_only_matmul_keys():
    params = {"layers": {"wq": jnp.ones((4, 8, 8)),
                         "norm1": jnp.ones((4, 8))},
              "embed": jnp.ones((16, 8)),
              "final_norm": jnp.ones((8,))}
    qt = quantize_tree(params, 8)
    from repro.quant import QTensor
    assert isinstance(qt["layers"]["wq"], QTensor)
    assert isinstance(qt["embed"], QTensor)
    assert isinstance(qt["layers"]["norm1"], jax.Array)   # untouched
    assert isinstance(qt["final_norm"], jax.Array)


def test_alpha_near_bits_ratio():
    """Measured alpha ~ bits/16 (paper's memory model), scale overhead small."""
    params = {"wq": jax.random.normal(jax.random.key(0), (512, 512),
                                      jnp.bfloat16),
              "w1": jax.random.normal(jax.random.key(1), (512, 2048),
                                      jnp.bfloat16)}
    fp = tree_bytes(params)
    for bits, target in ((8, 0.5), (4, 0.25)):
        alpha = tree_bytes(quantize_tree(params, bits)) / fp
        assert abs(alpha - target) < 0.02


def test_dequantize_tree_roundtrip_close():
    params = {"wq": jax.random.normal(jax.random.key(0), (64, 64))}
    deq = dequantize_tree(quantize_tree(params, 8))
    err = float(jnp.max(jnp.abs(deq["wq"] - params["wq"])))
    assert err < float(jnp.max(jnp.abs(params["wq"]))) / INT8_MAX


def test_scan_slicing_qtensor():
    """Stacked QTensors must slice layer-by-layer under lax.scan."""
    from repro.models.common import mm
    w = jax.random.normal(jax.random.key(0), (3, 16, 8))     # (L, K, N)
    t = quantize(w, 8)
    x = jax.random.normal(jax.random.key(1), (2, 16))

    def body(carry, wl):
        return carry + mm(x, wl), None

    out, _ = jax.lax.scan(body, jnp.zeros((2, 8)), t)
    want = sum(x @ w[i] for i in range(3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=0.05, atol=0.05)
