"""Per-batch quantization splits (DESIGN.md §1.1): the (z, method) split
descent, its measured weight-swap pricing, the split-aware policy
oracles, and split serving through BOTH runtimes.

The load-bearing inequality: a descent that includes every no-split
candidate can never schedule FEWER requests than the best single-method
schedule on the same queue — at any swap cost.  The committed
``experiments/benchmarks/quant_splits.json`` artifact pins the strict
win (>= 1.1x on at least one paper queue) from JSON alone, no
re-timing, exactly like the calibration-flip pin.
"""
from __future__ import annotations

import dataclasses
import json
import os
from types import SimpleNamespace

import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.dftsp import dftsp_schedule, dftsp_schedule_split
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv
from repro.core.policy import Decision, get_policy
from repro.core.quantization import METHODS, swap_seconds
from repro.core.request import RequestGenerator
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   ContinuousRuntime, EngineContinuousExecutor,
                                   EngineExecutor, EpochRuntime)

ENV = paper_env("bloom-3b", "W8A16")
ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "benchmarks", "quant_splits.json")


@pytest.fixture(scope="module")
def eng():
    from repro.serving.engine import ServingEngine
    return ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                         s_max=16, n_max=8, eos_id=-1)


def _queue(seed, rate=25.0, horizon=2.0):
    return RequestGenerator(rate=rate, seed=seed).within(0.0, horizon)


def _best_single(env, queue):
    return max(len(dftsp_schedule(env, queue, quant=m)[0])
               for m in METHODS.values())


def _flat_record(swap_s):
    """Synthetic swap record: every method canonicalizes by weight bits
    (W8A8/W8A16 share int8 residency and swap free), every cross-canon
    transition costs ``swap_s``."""
    return {"methods": {n: str(m.weight_bits) for n, m in METHODS.items()},
            "pairs": {}, "default_s": float(swap_s)}


def conserved(m):
    assert m.arrived == m.served + m.dropped + m.shed \
        + len(m.final_queue_rids) + len(m.in_flight_rids), \
        (m.arrived, m.served, m.dropped, m.shed,
         len(m.final_queue_rids), len(m.in_flight_rids))


# -- the descent -------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_split_never_loses_to_best_single(seed):
    queue = _queue(seed)
    single = _best_single(ENV, queue)
    for record in (None, _flat_record(0.01), _flat_record(10.0)):
        subs, _ = dftsp_schedule_split(ENV, queue, swap_record=record)
        assert sum(len(b) for b, _ in subs) >= single, (seed, record)


def test_split_strictly_wins_on_mixed_accuracy_queue():
    """Paper queue seed 0: the tight-accuracy tail rides its own W8A16
    sub-batch while the bulk serves at W8A8 — more requests than ANY
    single method admits."""
    queue = _queue(0)
    subs, _ = dftsp_schedule_split(ENV, queue)
    assert sum(len(b) for b, _ in subs) > _best_single(ENV, queue)
    assert len(subs) == 2
    assert len({m.name for _, m in subs}) == 2


def test_swap_cost_is_charged_and_prunes_cross_canon_splits():
    # seed 2's free split pairs W8A8 with W16A16 — a cross-canon swap
    queue = _queue(2)
    free, _ = dftsp_schedule_split(ENV, queue)
    assert len({str(m.weight_bits) for _, m in free}) == 2
    # a prohibitive measured swap kills every cross-canon split; the
    # descent still never drops below the best single method
    subs, _ = dftsp_schedule_split(ENV, queue,
                                   swap_record=_flat_record(1e3))
    assert len({str(m.weight_bits) for _, m in subs}) == 1
    total = sum(len(b) for b, _ in subs)
    assert _best_single(ENV, queue) <= total \
        <= sum(len(b) for b, _ in free)


def test_swap_seconds_lookup_contract():
    rec = _flat_record(5.0)
    assert swap_seconds(rec, METHODS["W8A8"], METHODS["W8A16"]) == 0.0
    assert swap_seconds(rec, METHODS["W8A8"], METHODS["W16A16"]) == 5.0
    assert swap_seconds(None, METHODS["W8A8"], METHODS["W16A16"]) == 0.0


# -- policy surface ----------------------------------------------------------


@pytest.mark.parametrize("spec", ["dftsp:quant=auto,split=true",
                                  "multi-dftsp:quant=auto,split=true",
                                  "dftsp:calib=measured,quant=auto,"
                                  "split=true"])
def test_split_spec_roundtrip(spec):
    assert get_policy(spec).spec == spec
    assert get_policy(get_policy(spec).spec).spec == spec


def test_split_decision_contract_and_oracle():
    p = get_policy("dftsp:quant=auto,split=true")
    dec = p.schedule(ENV, _queue(0))
    subs = dec.splits[None]
    assert len(subs) == 2
    # the flat batch is ALWAYS the concatenation of the sub-batches
    assert [r.rid for r in dec.batches[None]] == \
        [r.rid for b, _ in subs for r in b]
    # quants records the PRIMARY (first) sub-batch's method
    assert dec.quants[None].name == subs[0][1].name
    assert p.validate(ENV, dec)


def test_split_oracle_rejects_overfilled_sub_batch():
    p = get_policy("dftsp:quant=auto,split=true")
    dec = p.schedule(ENV, _queue(0))
    queue = _queue(0)
    extra = [r for r in queue
             if r.rid not in {x.rid for x in dec.batches[None]}]
    sub0, q0 = dec.splits[None][0]
    bad_sub = sub0 + extra
    bad = Decision(batches={None: bad_sub + dec.splits[None][1][0]},
                   quants=dict(dec.quants),
                   splits={None: [(bad_sub, q0), dec.splits[None][1]]})
    assert not p.validate(ENV, bad)


# -- epoch path: EngineExecutor serves splits sub-batch by sub-batch ---------


def test_engine_executor_admit_clamps_splits():
    p = get_policy("dftsp:quant=auto,split=true")
    dec = p.schedule(ENV, _queue(0))
    n0 = len(dec.splits[None][0][0])
    # clamp INSIDE the first sub-batch: the split collapses to one sub
    # and drops back to the flat form
    ex = EngineExecutor({None: SimpleNamespace(batch_capacity=n0 - 1)})
    clamped, spilled = ex.admit(ENV, p, dec)
    assert clamped.splits == {}
    assert len(clamped.batches[None]) == n0 - 1
    # clamp INSIDE the second sub-batch: both subs survive, truncated
    # from the back, and the flat batch stays the concatenation
    ex = EngineExecutor({None: SimpleNamespace(batch_capacity=n0 + 1)})
    clamped, spilled = ex.admit(ENV, p, dec)
    subs = clamped.splits[None]
    assert [len(b) for b, _ in subs] == [n0, 1]
    assert [r.rid for r in clamped.batches[None]] == \
        [r.rid for b, _ in subs for r in b]
    assert len(spilled) == len(dec.batches[None]) - (n0 + 1)


def test_engine_executor_executes_each_sub_at_its_own_method(eng):
    reqs = _queue(0)[:3]
    for r in reqs:
        r.n = 4
    dec = Decision(batches={None: list(reqs)},
                   quants={None: METHODS["W8A8"]},
                   splits={None: [(list(reqs[:2]), METHODS["W8A8"]),
                                  ([reqs[2]], METHODS["W16A16"])]})
    ex = EngineExecutor({None: eng}, seed=0)
    tokens = ex.execute(ENV, dec)
    # eos_id=-1: every row runs to its cap, so the split epoch generated
    # exactly the flat batch's token budget across both sub-batches
    assert tokens == sum(min(r.n, eng.n_max) for r in reqs)


def test_epoch_runtime_split_accounting_spans_methods(eng):
    rt = EpochRuntime(ENV, "dftsp:quant=auto,split=true",
                      EngineExecutor({None: eng}, seed=0))
    m = rt.run(rate=25, n_epochs=3, seed=0, warmup_epochs=0)
    conserved(m)
    assert m.served > 0
    # served_by_method follows the per-sub methods and stays conservative
    assert sum(m.served_by_method.values()) == m.served


# -- continuous path: split cohorts on both data planes ----------------------


def test_continuous_split_conservation_analytic():
    rt = ContinuousRuntime(ENV, "dftsp:quant=auto,split=true",
                           AnalyticContinuousExecutor(capacity=4), k=64)
    m = rt.run(gen=RequestGenerator(rate=20, seed=0), n_epochs=4,
               warmup_epochs=0)
    conserved(m)
    assert m.served > 0
    assert sum(m.served_by_method.values()) == m.served


def test_continuous_split_conservation_engine(eng):
    cexec = EngineContinuousExecutor(eng, seed=0, collect_tokens=True)
    rt = ContinuousRuntime(ENV, "dftsp:quant=auto,split=true", cexec, k=2)
    m = rt.run(gen=RequestGenerator(rate=8, seed=3, lengths=(4, 8)),
               n_epochs=4, warmup_epochs=0)
    conserved(m)
    assert m.served > 0
    served = [rid for t in m.traces for rid in t.finished_rids]
    assert len(served) == len(set(served)) == m.served
    assert sorted(cexec.outputs) == sorted(served)


def test_continuous_split_conservation_multi():
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })

    def tagger(arrivals):
        for i, r in enumerate(arrivals):
            r.model_id = "bloom-3b" if i % 2 == 0 else "bloom-7b1"
        return arrivals

    rt = ContinuousRuntime(menv, "multi-dftsp:quant=auto,split=true",
                           AnalyticContinuousExecutor(capacity=4), k=64)
    m = rt.run(gen=RequestGenerator(rate=12, seed=1), n_epochs=4,
               warmup_epochs=0, tag_arrivals=tagger)
    conserved(m)
    assert m.served > 0


def test_auto_calibrate_installs_measured_and_swap_records(eng):
    """Run-start warmup calibration on the engine data plane: a
    ``calib=measured`` split policy with nothing installed measures
    betas/alphas AND the swap record before the first admission."""
    p = get_policy("dftsp:calib=measured,quant=auto,split=true")
    assert p._measured is None and p._swap_record is None
    rt = ContinuousRuntime(ENV, p, EngineContinuousExecutor(eng, seed=0),
                           k=2)
    m = rt.run(gen=RequestGenerator(rate=4, seed=0, lengths=(4, 8)),
               n_epochs=2, warmup_epochs=0)
    conserved(m)
    assert p._measured is not None and set(p._measured) == set(METHODS)
    assert p._swap_record is not None and "pairs" in p._swap_record


# -- engine kernel routing (the use_kernel serving gap) ----------------------


def test_use_kernel_tokens_bit_identical(eng):
    from repro.serving.engine import ServingEngine
    ek = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=3,
                       s_max=16, n_max=8, eos_id=-1, use_kernel=True)
    prompts = [[3, 5, 7, 2], [1, 2], [9, 4, 6]]
    a = eng.generate(prompts, n_tokens=[8, 8, 8])
    b = ek.generate(prompts, n_tokens=[8, 8, 8])
    assert np.array_equal(a.tokens, b.tokens)
    assert np.array_equal(a.lengths, b.lengths)
    # chunked path too
    sa = eng.start_chunked(prompts, [8, 8, 8])
    sb = ek.start_chunked(prompts, [8, 8, 8])
    sa = eng.generate_chunked(sa, 8)
    sb = ek.generate_chunked(sb, 8)
    oa = eng.poll_chunked(sa)[0]
    ob = ek.poll_chunked(sb)[0]
    assert np.array_equal(oa, ob)


def test_use_kernel_rejects_non_transformer_families():
    from repro.serving.engine import ServingEngine
    with pytest.raises(ValueError):
        ServingEngine(reduced_cfg("xlstm-1.3b"), batch_capacity=2,
                      s_max=8, n_max=4, use_kernel=True)


def test_decode_tier_introspection(eng):
    # interpret-mode serving dequantizes weight-quant trees at load, so
    # every tier reports the unfused flash kernel on CPU; an int8 KV
    # deployment bypasses kernels entirely
    assert eng.decode_tier() in ("flash", "fused")
    from repro.kernels import ops as kops
    cfg8 = dataclasses.replace(eng.cfg, kv_bits=8)
    params = eng.params_for(eng.default_bits)
    layer = params.get("layers", params)
    assert kops.decode_kernel_tier(layer, cfg8) == "kv8"


# -- the committed artifact pin ----------------------------------------------


def test_pinned_quant_splits_artifact():
    """Re-derive every decision in the committed benchmark artifact from
    its saved swap record — no re-timing — and re-check the gates."""
    with open(ARTIFACT) as f:
        art = json.load(f)
    meta, header = art["meta"], art["header"]
    env = paper_env(meta["arch"], "W8A16")
    record = meta["record"]
    qmeta = meta["queue"]
    ratios = []
    for row in art["rows"]:
        row = dict(zip(header, row))
        queue = RequestGenerator(rate=qmeta["rate"],
                                 seed=row["queue_seed"]).within(
            0.0, qmeta["horizon"])
        assert len(queue) == row["n_queue"]
        assert _best_single(env, queue) == row["single_batch"]
        subs, _ = dftsp_schedule_split(env, queue, swap_record=record)
        assert sum(len(b) for b, _ in subs) == row["split_measured"]
        ratios.append(row["ratio"])
    gate = meta["gate"]
    assert all(r >= gate["floor"] for r in ratios)
    assert any(r >= gate["win"] for r in ratios)


# -- hypothesis property (CI installs hypothesis; local runs skip) -----------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), rate=st.floats(5.0, 40.0),
           swap_s=st.floats(0.0, 20.0))
    def test_split_dominates_best_single_property(seed, rate, swap_s):
        """At ANY swap cost, the split descent never schedules fewer
        requests than the best single-method schedule — the no-split
        candidates are part of its search space."""
        queue = _queue(seed, rate=rate)
        subs, _ = dftsp_schedule_split(ENV, queue,
                                       swap_record=_flat_record(swap_s))
        total = sum(len(b) for b, _ in subs)
        assert total >= _best_single(ENV, queue)
        # the flat concatenation never duplicates a request
        rids = [r.rid for b, _ in subs for r in b]
        assert len(rids) == len(set(rids))
