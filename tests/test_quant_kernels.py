"""Quantized kernel tiers: W8A8 int8-accumulation, int4 unpack identity,
activation-quant round-trip, and the fused quantized flash-decode vs its
unfused composition (contiguous + paged layouts)."""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import common
from repro.quant.ptq import (pack_int4, quantize, quantize_rowwise,
                             unpack_int4)

QMM_SHAPES = [(128, 256, 128), (64, 512, 384), (4, 300, 200),
              (1, 128, 128), (130, 260, 76)]


# ---------------------------------------------------------------------------
# W8A8: int8 x int8 -> int32 accumulation, one rescale at writeout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", QMM_SHAPES)
def test_w8a8_bitwise_vs_oracle(shape):
    """The blocked int32 accumulation is EXACT integer math, and scales
    are computed identically (reciprocal multiply) in kernel and oracle,
    so kernel == oracle bit for bit — including padding-remainder
    shapes, where stray garbage in the pad region would break this."""
    M, K, N = shape
    x = jax.random.normal(jax.random.key(1), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.key(2), (K, N), jnp.float32)
    t = quantize(w, 8, act_bits=8)
    got = ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8, act_bits=8)
    want = ref.quant_matmul_a8_ref(x, t.q, t.scale.reshape(-1))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", QMM_SHAPES)
def test_w8a8_analytic_bound_vs_f32(shape):
    """|W8A8 - x @ dequant(w)| is bounded by the activation rounding:
    each row's quantization error is <= sx/2 per element, so the output
    error is <= (sx_i / 2) * sum_k |wdq[k, j]| elementwise."""
    M, K, N = shape
    x = jax.random.normal(jax.random.key(3), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.key(4), (K, N), jnp.float32)
    t = quantize(w, 8, act_bits=8)
    wdq = t.q.astype(jnp.float32) * t.scale.astype(jnp.float32)
    got = np.asarray(ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8,
                                      act_bits=8))
    want = np.asarray(x @ wdq)
    _, sx = quantize_rowwise(x)
    bound = 0.5 * np.asarray(sx) * np.abs(np.asarray(wdq)).sum(0)[None, :]
    assert np.all(np.abs(got - want) <= bound + 1e-5)


def test_w8a8_close_to_w8a16():
    """Same int8 weights consumed by both activation tiers: the a8 path
    only adds the (bounded) dynamic activation rounding."""
    x = jax.random.normal(jax.random.key(5), (64, 256), jnp.float32)
    w = jax.random.normal(jax.random.key(6), (256, 192), jnp.float32)
    t = quantize(w, 8)
    a16 = np.asarray(ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8))
    a8 = np.asarray(ops.quant_matmul(x, t.q, t.scale.reshape(-1), 8,
                                     act_bits=8))
    scale = np.abs(a16).max()
    assert np.abs(a8 - a16).max() <= 0.02 * scale


# ---------------------------------------------------------------------------
# int4 unpack: index-free even/odd reconstruction
# ---------------------------------------------------------------------------


def _unpack_int4_stack(packed):
    """The historical stack+reshape interleave unpack (bitwise oracle for
    the index-free rewrite)."""
    lo = ((packed << 4) >> 4).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    inter = jnp.stack([lo, hi], axis=-2)     # (..., R/2, 2, C) interleave
    shape = list(packed.shape)
    shape[-2] *= 2
    return inter.reshape(shape)


@pytest.mark.parametrize("shape", [(8, 16), (30, 7), (3, 10, 12)])
def test_unpack_int4_bitwise_matches_stack(shape):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-8, 8, size=shape), jnp.int8)
    packed = pack_int4(q)
    got = np.asarray(unpack_int4(packed))
    want = np.asarray(_unpack_int4_stack(packed))
    assert np.array_equal(got, want)
    # and both invert pack_int4 exactly
    assert np.array_equal(got[..., :shape[-2], :], np.asarray(q))


def test_quantize_rowwise_roundtrip():
    """|x - q * s| <= s/2 elementwise (symmetric RTN never clips)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(17, 33)) * 100.0, jnp.float32)
    q, s = quantize_rowwise(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * np.asarray(s))
    assert np.all(err <= 0.5 * np.asarray(s) + 1e-7)
    assert np.all(np.asarray(s) > 0)


def test_quantize_rowwise_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False,
                              allow_infinity=False, width=32),
                    min_size=1, max_size=64))
    def prop(vals):
        x = jnp.asarray([vals], jnp.float32)
        q, s = quantize_rowwise(x)
        err = np.abs(np.asarray(x)
                     - np.asarray(q, np.float32) * np.asarray(s))
        assert np.all(err <= 0.5 * np.asarray(s) + 1e-6)

    prop()


# ---------------------------------------------------------------------------
# Fused quantized flash-decode vs unfused composition
# ---------------------------------------------------------------------------

B, D, NH, NKV, DH, W = 3, 64, 4, 2, 32, 16
THETA = 1e4
CFG = SimpleNamespace(d_head=DH, n_heads=NH, n_kv_heads=NKV,
                      rope_theta=THETA, qk_norm=False, kv_bits=0,
                      sliding_window=0)


def _fused_params(act_bits, seed=0):
    rng = np.random.default_rng(seed)

    def qw(shape):
        w = jnp.asarray(rng.normal(size=shape), jnp.float32) * 0.2
        return quantize(w, 8, act_bits=act_bits)

    return {"wq": qw((D, NH * DH)), "wk": qw((D, NKV * DH)),
            "wv": qw((D, NKV * DH)), "wo": qw((NH * DH, D))}, \
        jnp.asarray(rng.normal(size=(B, 1, D)), jnp.float32)


def _tol(act_bits):
    # a16: fused == unfused up to f32 accumulation order.  a8: the fused
    # wo projection quantizes per-head-group attention rows (G*dh) while
    # the unfused path sees the full (nh*dh) row — a different dynamic
    # scale, hence the documented looser bound.
    return 1e-4 if act_bits == 16 else 0.15


@pytest.mark.parametrize("act_bits", [16, 8])
@pytest.mark.parametrize("pos_v", [0, 5, W, W + 7])
def test_fused_decode_matches_unfused(act_bits, pos_v):
    """pos sweep covers: empty cache (the all-masked online-softmax pass
    must wash out), partial fill, the wrap boundary, and eviction."""
    p, x = _fused_params(act_bits)
    rng = np.random.default_rng(10 + pos_v)
    pos = jnp.int32(pos_v)
    valid = (np.arange(W) < min(pos_v, W)).astype(np.float32)
    ck = jnp.asarray(rng.normal(size=(B, W, NKV, DH)) *
                     valid[None, :, None, None], jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, W, NKV, DH)) *
                     valid[None, :, None, None], jnp.float32)

    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k1, v1 = common.qkv_proj(p, CFG, x, positions, True)
    ck2, cv2 = common.cache_write(ck, cv, k1, v1, pos)
    out = ops.flash_decode(q[:, 0], ck2, cv2, jnp.minimum(pos + 1, W))
    out = common.mm(out.reshape(B, 1, NH * DH), p["wo"])[:, 0]

    o, k1f, v1f = ops.flash_decode_fused(
        x[:, 0], p["wq"], p["wk"], p["wv"], p["wo"], ck, cv, pos,
        rope_theta=THETA)

    np.testing.assert_allclose(np.asarray(k1f), np.asarray(k1[:, 0]),
                               atol=1e-5, rtol=0)
    np.testing.assert_allclose(np.asarray(v1f), np.asarray(v1[:, 0]),
                               atol=1e-5, rtol=0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(out),
                               atol=_tol(act_bits), rtol=0)


@pytest.mark.parametrize("act_bits", [16, 8])
@pytest.mark.parametrize("pos_v", [0, 5, 11])
def test_fused_decode_paged_matches_unfused(act_bits, pos_v):
    bt, n_b, P = 8, 2, 7
    p, x = _fused_params(act_bits, seed=1)
    rng = np.random.default_rng(20 + pos_v)
    pos = jnp.int32(pos_v)
    kp = rng.normal(size=(P, bt, NKV, DH)).astype(np.float32)
    vp = rng.normal(size=(P, bt, NKV, DH)).astype(np.float32)
    tbl = rng.permutation(P)[:B * n_b].reshape(B, n_b)
    for b in range(B):            # zero logical slots >= pos (unwritten)
        for j in range(n_b):
            for t in range(bt):
                if j * bt + t >= pos_v:
                    kp[tbl[b, j], t] = 0
                    vp[tbl[b, j], t] = 0
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    table = jnp.asarray(tbl, jnp.int32)

    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k1, v1 = common.qkv_proj(p, CFG, x, positions, True)
    blk, off = pos_v // bt, pos_v % bt
    pk = kp.at[table[:, blk], off].set(k1[:, 0])
    pv = vp.at[table[:, blk], off].set(v1[:, 0])
    out = ops.flash_decode_paged(q[:, 0], pk, pv, table,
                                 jnp.minimum(pos + 1, bt * n_b))
    out = common.mm(out.reshape(B, 1, NH * DH), p["wo"])[:, 0]

    o, k1f, v1f = ops.flash_decode_fused_paged(
        x[:, 0], p["wq"], p["wk"], p["wv"], p["wo"], kp, vp, table, pos,
        rope_theta=THETA)

    np.testing.assert_allclose(np.asarray(k1f), np.asarray(k1[:, 0]),
                               atol=1e-5, rtol=0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(out),
                               atol=_tol(act_bits), rtol=0)


def test_decode_attention_fused_route_matches():
    """models.common.decode_attention(use_kernel=True) takes the fused
    path for all-int8 params and must agree with the reference route —
    output AND the caches it writes."""
    p, x = _fused_params(16)
    assert ops.fusable_decode(p, CFG)
    pos = jnp.int32(5)
    ck = jnp.zeros((B, W, NKV, DH), jnp.float32)
    cv = jnp.zeros((B, W, NKV, DH), jnp.float32)
    o_ref, ckr, cvr = common.decode_attention(p, CFG, x, ck, cv, pos,
                                              use_kernel=False)
    o_fus, ckf, cvf = common.decode_attention(p, CFG, x, ck, cv, pos,
                                              use_kernel=True)
    np.testing.assert_allclose(np.asarray(o_fus), np.asarray(o_ref),
                               atol=1e-4, rtol=0)
    np.testing.assert_allclose(np.asarray(ckf), np.asarray(ckr),
                               atol=1e-5, rtol=0)
    np.testing.assert_allclose(np.asarray(cvf), np.asarray(cvr),
                               atol=1e-5, rtol=0)


def test_fusable_decode_gating():
    p16, _ = _fused_params(16)
    assert ops.fusable_decode(p16, CFG)
    # fp params (no QTensors) must not take the quantized fused path
    fp = {k: jnp.zeros((2, 2)) for k in ("wq", "wk", "wv", "wo")}
    assert not ops.fusable_decode(fp, CFG)
    cfg_qk = SimpleNamespace(**{**CFG.__dict__, "qk_norm": True})
    assert not ops.fusable_decode(p16, cfg_qk)
