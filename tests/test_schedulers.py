"""Baseline schedulers + epoch simulation (paper §IV mechanics)."""
from __future__ import annotations

import pytest

from repro.core import problem, schedulers
from repro.core.environment import paper_env, tpu_env
from repro.core.request import Request, RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

ENV = paper_env("bloom-3b", "W8A16")


def simulate(env, policy, rate, n_epochs=30, seed=0):
    return EpochRuntime(env, policy, AnalyticExecutor()).run(
        rate=rate, n_epochs=n_epochs, seed=seed)


def test_static_batch_size_is_feasible_worst_case():
    B = schedulers.static_batch_size(ENV)
    assert B >= 1
    worst = [Request(i, 512, 512, 10.0, 0.0, 0.05) for i in range(B)]
    cm = ENV.cost_model()
    q = ENV.quant
    mem = (q.alpha_w * cm.weight_bytes()
           + q.alpha_a * (cm.kv_bytes_prefill(ENV.s_max, B)
                          + cm.kv_bytes_decode([512] * B, ENV.s_max)))
    assert mem <= ENV.M


def test_every_scheduler_returns_feasible(seed=1):
    gen = RequestGenerator(rate=30, seed=seed)
    reqs = gen.within(0, 2.0)
    for name in ("dftsp", "stb", "greedy", "brute_force"):
        sel, _ = schedulers.get_scheduler(name)(ENV, reqs)
        assert problem.feasible(ENV, sel), name
    sel, _ = schedulers.no_batching(ENV, reqs)
    assert schedulers.nob_feasible(ENV, sel)


def test_dftsp_dominates_heuristics():
    """Across seeds, the optimal scheduler can never lose to StB/NoB/greedy."""
    for seed in range(5):
        gen = RequestGenerator(rate=25, seed=seed)
        reqs = gen.within(0, 2.0)
        z_opt = len(schedulers.dftsp(ENV, reqs)[0])
        for name in ("stb", "greedy"):
            z = len(schedulers.get_scheduler(name)(ENV, reqs)[0])
            assert z <= z_opt, (name, seed)


def test_simulation_deterministic():
    r1 = simulate(ENV, "dftsp", rate=10, n_epochs=5, seed=7)
    r2 = simulate(ENV, "dftsp", rate=10, n_epochs=5, seed=7)
    assert r1.served == r2.served and r1.nodes_visited == r2.nodes_visited


def test_simulation_conservation():
    res = simulate(ENV, "dftsp", rate=10, n_epochs=8, seed=0)
    assert res.served + res.dropped <= res.arrived + 64  # queue remainder
    assert res.throughput >= 0


def test_paper_fig5a_ordering():
    """DFTSP >= StB and >= NoB in served throughput (Fig. 5a claim)."""
    thr = {s: simulate(ENV, s, rate=20, n_epochs=10).throughput
           for s in ("dftsp", "stb", "nob")}
    assert thr["dftsp"] >= thr["stb"]
    assert thr["dftsp"] >= thr["nob"]


def test_table3_pruning_reduces_nodes():
    res_fast = simulate(ENV, "dftsp", rate=20, n_epochs=6, seed=3)
    res_slow = simulate(ENV, "brute_force", rate=20, n_epochs=6, seed=3)
    assert res_fast.served == res_slow.served       # same optimum
    assert res_fast.nodes_visited < res_slow.nodes_visited


def test_tpu_env_higher_throughput_than_paper_env():
    """A v5e-16 slice has ~100x the FLOPs of 20 Jetson TX2s."""
    env_tpu = tpu_env("bloom-3b", chips=16)
    r_paper = simulate(ENV, "dftsp", rate=40, n_epochs=6, seed=0)
    r_tpu = simulate(env_tpu, "dftsp", rate=40, n_epochs=6, seed=0)
    assert r_tpu.served >= r_paper.served
