"""Sharding rules, logical-axis plumbing, and HLO roofline parsing.

These run on the host device count (1 CPU) — they exercise the rule
logic, not the 512-device lowering (that's the dry-run's job).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (_split_computations, analytic_costs,
                                     collective_bytes, dominant_term,
                                     model_flops, roofline_terms)
from repro.config import get_arch, get_shape
from repro.utils.sharding import axis_ctx, axis_divisor, constrain, logical_spec


# ---------------------------------------------------------------------------
# logical axis context
# ---------------------------------------------------------------------------


def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_logical_spec_resolution():
    with axis_ctx(batch=("pod", "data"), model="model",
                  sizes={"pod": 2, "data": 16, "model": 16}):
        assert logical_spec("batch", None, "model") == \
            P(("pod", "data"), None, "model")
        assert axis_divisor("model") == 16
        assert axis_divisor("batch") == 32
        # divisibility fallback: 56 not divisible by 16 => replicated dim
        spec = logical_spec("batch", "model", shape=(64, 56))
        assert spec == P(("pod", "data"), None)


def test_param_specs_rules():
    from repro.launch.steps import param_specs
    from repro.models.api import build_model

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("data", "model")

    cfg = get_arch("qwen3-1.7b")
    model = build_model(cfg)
    specs = param_specs(model, FakeMesh(), fsdp=True)
    # wq stacked (L, dm, nh*dh): col-parallel + fsdp on dm
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    # wo stacked (L, nh*dh, dm): row-parallel on -2
    assert specs["layers"]["attn"]["wo"][-2] == "model"
    # embed (V, dm): col-parallel on dm, fsdp on V
    assert specs["embed"] == P("data", "model")
    no_fsdp = param_specs(model, FakeMesh(), fsdp=False)
    assert no_fsdp["embed"] == P(None, "model")


def test_moe_expert_parallel_rule():
    from repro.launch.steps import param_specs
    from repro.models.api import build_model

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    # granite: 32 experts % 16 == 0 => expert-parallel
    specs = param_specs(build_model(get_arch("granite-moe-1b-a400m")),
                        FakeMesh(), fsdp=False)
    assert specs["layers"]["moe"]["w1"][1] == "model"
    # mixtral: 8 experts, not divisible => hidden-dim fallback
    specs = param_specs(build_model(get_arch("mixtral-8x22b")),
                        FakeMesh(), fsdp=False)
    assert specs["layers"]["moe"]["w1"] == P(None, None, None, "model")
    assert specs["layers"]["moe"]["w2"] == P(None, None, "model", None)


def test_cache_specs_batch_detection():
    from repro.launch.steps import cache_specs

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_arch("olmo-1b")   # n_layers=16 == could collide with batch
    leaves = {"k": jax.ShapeDtypeStruct((16, 128, 32768, 16, 128),
                                        jnp.bfloat16)}
    specs = cache_specs(cfg, FakeMesh(), leaves, batch=128)
    # batch (=128) at axis 1, slots at axis 2; L=16 NOT mistaken for batch
    assert specs["k"] == P(None, ("data",), "model", None, None)


def test_cache_specs_b1_long_context():
    from repro.launch.steps import cache_specs

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    leaves = {"k": jax.ShapeDtypeStruct((56, 1, 4096, 8, 128), jnp.bfloat16)}
    specs = cache_specs(get_arch("mixtral-8x22b"), FakeMesh(), leaves,
                        batch=1)
    assert specs["k"][2] == "model"     # slots sharded, batch replicated


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

FAKE_HLO = """
HloModule jit_step

%body.1 (arg: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %x = f32[8,128] parameter(0)
  %ar = f32[8,128] all-reduce(%x), replica_groups={}
  ROOT %t = (f32[8,128], s32[]) tuple(%ar, %i)
}

%cond.1 (arg: (f32[8,128], s32[])) -> pred[] {
  %i = s32[] get-tuple-element(%arg), index=1
  %limit = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %limit), direction=LT
}

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128] parameter(0)
  %ag = f32[128,128] all-gather(%p), dimensions={0}
  %w = (f32[8,128], s32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,128] get-tuple-element(%w), index=0
}
"""


def test_collective_bytes_loop_aware():
    out = collective_bytes(FAKE_HLO)
    # all-gather outside the loop: 128*128*4 bytes once
    assert out["all-gather"] == 128 * 128 * 4
    # all-reduce inside a 24-trip while: 8*128*4 * 24
    assert out["all-reduce"] == 8 * 128 * 4 * 24
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_split_computations_finds_entry():
    comps = _split_computations(FAKE_HLO)
    assert "main" in comps and "body.1" in comps and "cond.1" in comps


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def test_roofline_term_math():
    terms = roofline_terms(197e12 * 256, 819e9 * 256, 50e9 * 256, 256)
    assert terms["t_compute"] == pytest.approx(1.0)
    assert terms["t_memory"] == pytest.approx(1.0)
    assert terms["t_collective"] == pytest.approx(1.0)
    assert dominant_term({"t_compute": 3, "t_memory": 1,
                          "t_collective": 2}) == "t_compute"


def test_analytic_costs_scale_sanely():
    cfg = get_arch("qwen3-1.7b")
    f_train, b_train = analytic_costs(cfg, get_shape("train_4k"))
    f_dec, b_dec = analytic_costs(cfg, get_shape("decode_32k"))
    # train moves ~6ND flops; decode is ~2ND per token
    assert f_train / model_flops(cfg, get_shape("train_4k")) < 2.0
    assert f_train > 100 * f_dec
    # decode arithmetic intensity (flops/byte) must be tiny vs train
    assert (f_dec / b_dec) < 0.05 * (f_train / b_train)


def test_moe_model_flops_active_only():
    cfg = get_arch("mixtral-8x22b")
    mf = model_flops(cfg, get_shape("train_4k"))
    assert mf < 6.0 * cfg.param_count() * 0.5 * get_shape(
        "train_4k").global_batch * get_shape("train_4k").seq_len
