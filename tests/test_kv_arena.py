"""Paged KV arena (DESIGN.md §2.3): the kernel's block-table indirection
must be BIT-identical to the contiguous oracle, the allocator must never
double-lease a page, the arena-backed engine path must reproduce the
slab path token-for-token across every PR-3/PR-4 edge case (cap=0,
immediate EOS, padding-only rows, quant 0/8/4, int8 KV, mid-cohort
refill), and the continuous executor must gate admission on free pages
while returning every lease at completion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, random_tagger
from repro.core.request import Request, RequestGenerator
from repro.kernels import ops
from repro.serving.engine import ServingEngine, tiny_engine
from repro.serving.kv_arena import (N_RESERVED, TRASH_PAGE, ZERO_PAGE,
                                    ArenaError, ArenaExhausted, BlockTable,
                                    KVArena)
from repro.serving.runtime import ContinuousRuntime, EngineContinuousExecutor

# -- paged flash-decode kernel: bit-identity to the contiguous oracle --------

PAGED_FD_CASES = [
    # (B, nh, nkv, dh, W, bt) — GQA, MHA, MQA; bt in {16, 64}; dh that
    # needs lane padding (80) and dh that doesn't (64/128)
    (4, 8, 2, 128, 256, 16),
    (3, 4, 4, 64, 128, 64),
    (2, 6, 6, 128, 64, 16),
    (2, 8, 1, 80, 128, 16),
]


def _paged_layout(k, v, bt, seed):
    """Scatter a contiguous (B, W, nkv, dh) cache into a scrambled
    physical page pool, garbage everywhere a logical block doesn't
    live."""
    B, W, nkv, dh = k.shape
    nb = W // bt
    P = N_RESERVED + B * nb + 3
    rng = np.random.default_rng(seed)
    phys = rng.permutation(np.arange(N_RESERVED, P))[:B * nb]
    table = phys.reshape(B, nb).astype(np.int32)
    kp = jax.random.normal(jax.random.key(90 + seed), (P, bt, nkv, dh),
                           k.dtype)
    vp = jax.random.normal(jax.random.key(91 + seed), (P, bt, nkv, dh),
                           v.dtype)
    kb = k.reshape(B, nb, bt, nkv, dh)
    vb = v.reshape(B, nb, bt, nkv, dh)
    for b in range(B):
        for j in range(nb):
            kp = kp.at[table[b, j]].set(kb[b, j])
            vp = vp.at[table[b, j]].set(vb[b, j])
    return kp, vp, jnp.asarray(table)


@pytest.mark.parametrize("case", PAGED_FD_CASES)
def test_paged_flash_decode_bit_identical_to_contiguous(case):
    B, nh, nkv, dh, W, bt = case
    q = jax.random.normal(jax.random.key(1), (B, nh, dh), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, W, nkv, dh), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, W, nkv, dh), jnp.float32)
    nv = np.random.default_rng(case[0]).integers(1, W + 1, B)
    kp, vp, table = _paged_layout(k, v, bt, seed=7)
    got = ops.flash_decode_paged(q, kp, vp, table, jnp.asarray(nv))
    # BITWISE equality against the contiguous kernel at block_s == bt:
    # the paged grid walks the same logical blocks in the same order with
    # the same arithmetic — the physical scramble must be invisible
    want = ops.flash_decode(q, k, v, jnp.asarray(nv), block_s=bt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and numerically equal to the default blocking (different online-
    # softmax accumulation order, same attention)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ops.flash_decode(q, k, v,
                                                     jnp.asarray(nv))),
        rtol=2e-5, atol=2e-5)


def test_paged_flash_decode_ragged_includes_block_edges():
    """n_valid exactly on, one under, and one over block boundaries."""
    B, nh, nkv, dh, W, bt = 6, 4, 2, 64, 128, 16
    q = jax.random.normal(jax.random.key(4), (B, nh, dh), jnp.float32)
    k = jax.random.normal(jax.random.key(5), (B, W, nkv, dh), jnp.float32)
    v = jax.random.normal(jax.random.key(6), (B, W, nkv, dh), jnp.float32)
    nv = jnp.asarray([1, bt - 1, bt, bt + 1, W - 1, W])
    kp, vp, table = _paged_layout(k, v, bt, seed=11)
    got = ops.flash_decode_paged(q, kp, vp, table, nv)
    want = ops.flash_decode(q, k, v, nv, block_s=bt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- allocator ---------------------------------------------------------------


def _tiny_specs():
    return {"k": jax.ShapeDtypeStruct((1, 1, 8, 2, 4), jnp.float32),
            "v": jax.ShapeDtypeStruct((1, 1, 8, 2, 4), jnp.float32)}


def test_arena_alloc_free_roundtrip():
    arena = KVArena(_tiny_specs(), n_pages=10, block_tokens=8)
    assert arena.total_pages == 10 - N_RESERVED
    assert arena.free_pages == arena.total_pages
    a = arena.alloc(3)
    b = arena.alloc(2)
    assert len(set(a) | set(b)) == 5                # disjoint leases
    assert all(p >= N_RESERVED for p in a + b)      # reserved never leased
    assert arena.pages_in_use == 5
    arena.free(a)
    arena.free(b)
    assert arena.free_pages == arena.total_pages
    assert arena.alloc_peak == 5


def test_arena_exhaustion_raises():
    arena = KVArena(_tiny_specs(), n_pages=5, block_tokens=8)
    arena.alloc(arena.total_pages)
    with pytest.raises(ArenaExhausted):
        arena.alloc(1)


def test_arena_buffer_layout_and_zero_init():
    arena = KVArena(_tiny_specs(), n_pages=6, block_tokens=8)
    for leaf in arena.buffers().values():
        assert leaf.shape == (1, 6, 8, 2, 4)
        assert not np.asarray(leaf).any()           # ZERO_PAGE relies on it


def test_block_table_rows_and_leases():
    tbl = BlockTable(batch=3, n_blocks=4)
    assert tbl.row_leases(0) == []                  # all TRASH initially
    tbl.set_row(1, [5, ZERO_PAGE, 6, 7])
    assert tbl.row_leases(1) == [5, 6, 7]           # reserved ids excluded
    dev0 = tbl.device
    tbl.clear_row(1)
    assert tbl.row_leases(1) == []
    assert np.all(tbl.host[1] == TRASH_PAGE)
    assert tbl.device is not dev0                   # mutation re-ships


def test_arena_free_rejects_double_free_and_reserved_pages():
    """The free-path guards are REAL ``ArenaError`` raises, not asserts
    — CI re-runs this file under ``python -O`` (which strips asserts)
    and these ``pytest.raises`` blocks must still bite there."""
    arena = KVArena(_tiny_specs(), n_pages=10, block_tokens=8)
    lease = arena.alloc(2)
    arena.free(lease)
    with pytest.raises(ArenaError, match="double free"):
        arena.free([lease[0]])
    for p in range(N_RESERVED):
        with pytest.raises(ArenaError, match="reserved"):
            arena.free([p])
    # failed frees must not have mutated the free list
    assert arena.free_pages == arena.total_pages
    assert len(set(arena.alloc(arena.total_pages))) == arena.total_pages


def test_arena_free_rejects_out_of_range_page_ids():
    """Regression: an out-of-range id handed to ``free`` used to grow
    the free list silently, letting a later ``alloc`` lease a page the
    device buffers don't have."""
    arena = KVArena(_tiny_specs(), n_pages=10, block_tokens=8)
    free0 = arena.free_pages
    for bogus in (arena.n_pages, arena.n_pages + 7, 99):
        with pytest.raises(ArenaError, match="out-of-range"):
            arena.free([bogus])
    assert arena.free_pages == free0
    got = arena.alloc(arena.free_pages)
    assert all(N_RESERVED <= p < arena.n_pages for p in got)


def test_arena_free_list_keeps_lifo_reuse_order():
    """The set-backed membership check must not change reuse order:
    most-recently-freed pages are leased first (warm pages stay warm)."""
    arena = KVArena(_tiny_specs(), n_pages=12, block_tokens=8)
    a = arena.alloc(3)
    arena.free(a)
    assert arena.alloc(3) == a[::-1]


def test_block_table_validates_page_ids_and_extends_rows():
    """``set_row``/``extend_row`` on a pool-bound table reject negative
    and beyond-pool page ids without partially mutating the row;
    ``extend_row`` splices a lease tail in place."""
    tbl = BlockTable(batch=2, n_blocks=3, n_pages=8)
    with pytest.raises(ArenaError, match="out of range"):
        tbl.set_row(0, [2, 3, 8])
    with pytest.raises(ArenaError, match="out of range"):
        tbl.set_row(0, [-1, 3, 4])
    assert tbl.row_leases(0) == []                  # row untouched
    tbl.set_row(0, [2, 3, TRASH_PAGE])
    with pytest.raises(ArenaError, match="out of range"):
        tbl.extend_row(0, 2, [8])
    assert tbl.row_leases(0) == [2, 3]
    tbl.extend_row(0, 2, [7])
    assert tbl.row_leases(0) == [2, 3, 7]
    # an unbound table (no pool size known) keeps the legacy behavior
    BlockTable(batch=1, n_blocks=2).set_row(0, [5, 99])


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_arena_never_double_allocates(data):
        """Random alloc/free interleavings: live leases stay disjoint,
        reserved pages never leave the pool, and freeing everything
        restores the initial free count."""
        n_pages = data.draw(st.integers(N_RESERVED + 1, 24))
        arena = KVArena(_tiny_specs(), n_pages, block_tokens=8)
        live = []
        for _ in range(data.draw(st.integers(1, 30))):
            if live and data.draw(st.booleans()):
                i = data.draw(st.integers(0, len(live) - 1))
                arena.free(live.pop(i))
            else:
                n = data.draw(st.integers(0, arena.free_pages))
                lease = arena.alloc(n)
                flat = [p for ls in live for p in ls]
                assert not set(lease) & set(flat)
                assert all(p >= N_RESERVED for p in lease)
                live.append(lease)
            held = sum(len(ls) for ls in live)
            assert arena.free_pages + held == arena.total_pages
        for ls in live:
            arena.free(ls)
        assert arena.free_pages == arena.total_pages

    _PROP_ENG = {}

    def _prop_engine():
        # one reduced engine shared across examples (construction re-jits
        # the segment loops; the schedule varies, the engine need not).
        # eos_id=-1 can never be sampled, so non-evicted rows ALWAYS run
        # to their cap — the case where reservation == leases is exact.
        if not _PROP_ENG:
            _PROP_ENG["eng"] = tiny_engine("bloom-3b", batch_capacity=3,
                                           s_max=8, n_max=8, eos_id=-1)
        return _PROP_ENG["eng"]

    @settings(max_examples=6, deadline=None)
    @given(data=st.data())
    def test_admission_reservation_equals_pages_leased(data):
        """Across random admission steps, caps, refill times, chunk
        sizes and evictions: the pages ``pages_for_admission`` reserved
        for a row exactly equal the pages it has leased (initial lease +
        boundary top-ups) by the time it runs to its cap, never-exceeded
        for rows evicted early, the paged cohort stays bitwise identical
        to an identically-driven slab twin, and the arena drains."""
        eng = _prop_engine()
        bt = data.draw(st.sampled_from([4, 8]))
        arena = KVArena.for_engines([eng], block_tokens=bt)
        B, n_max = eng.batch_capacity, eng.n_max
        rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))

        def mk_prompt():
            s = int(rng.integers(1, eng.s_max + 1))
            return rng.integers(1, eng.cfg.vocab, size=s).tolist()

        n0 = data.draw(st.integers(1, B))
        prompts = [mk_prompt() for _ in range(n0)]
        caps = [data.draw(st.integers(1, n_max)) for _ in range(n0)]
        sp = eng.start_chunked(prompts, caps, arena=arena)
        ss = eng.start_chunked(prompts, caps)
        res = {b: eng.pages_for_admission(0, caps[b], bt)
               for b in range(n0)}
        for b in res:
            assert len(sp.table.row_leases(b)) <= res[b]
        free_slots = list(range(n0, B))
        for _ in range(24):
            k = data.draw(st.integers(1, 4))
            sp = eng.generate_chunked(sp, k)
            ss = eng.generate_chunked(ss, k)
            op, lp, dp, tp = eng.poll_chunked(sp)
            os_, ls_, ds_, ts_ = eng.poll_chunked(ss)
            np.testing.assert_array_equal(op, os_)      # bitwise twin
            np.testing.assert_array_equal(lp, ls_)
            np.testing.assert_array_equal(dp, ds_)
            assert tp == ts_
            done_now = [b for b in list(res)
                        if lp[b] >= sp.caps_host[b] and not dp[b]]
            for b in done_now:                          # ran to cap:
                assert len(sp.table.row_leases(b)) == res.pop(b), b
            # park finished rows on BOTH states the same way (evict flags
            # done + zeroes caps on either state type, and returns the
            # paged row's leases) so the twins stay bitwise comparable
            sp = eng.evict_slots(sp, done_now)
            ss = eng.evict_slots(ss, done_now)
            free_slots += done_now
            if res and data.draw(st.booleans()):        # random preemption
                b = data.draw(st.sampled_from(sorted(res)))
                assert len(sp.table.row_leases(b)) <= res.pop(b)
                sp = eng.evict_slots(sp, [b])
                ss = eng.evict_slots(ss, [b])
                free_slots.append(b)
            if free_slots and eng.headroom(tp) > 0 \
                    and data.draw(st.booleans()):       # random refill
                b = free_slots.pop(data.draw(
                    st.integers(0, len(free_slots) - 1)))
                cap = min(data.draw(st.integers(1, n_max)),
                          eng.headroom(tp))
                p = [mk_prompt()]
                sp = eng.refill_chunked(sp, [b], p, [cap], t_now=tp)
                ss = eng.refill_chunked(ss, [b], p, [cap], t_now=tp)
                assert sp.caps_host[b] == cap
                res[b] = eng.pages_for_admission(tp, cap, bt)
            if not res:                                 # everyone settled
                break
        assert not res                                  # everyone settled
        eng.release_all(sp)
        assert arena.free_pages == arena.total_pages    # fully drained


# -- for_engines sizing / geometry validation --------------------------------


def _fake_engine(cache_len=32, shape=(1, 1, 32, 2, 8),
                 dtype=jnp.bfloat16, leaves=("k", "v"), batch=2):
    class _Model:
        @staticmethod
        def init_cache(b, w):
            return {n: jnp.zeros(shape, dtype) for n in leaves}

    class _Eng:
        paged_capable = True
        model = _Model()
    e = _Eng()
    e.cache_len = cache_len
    e.batch_capacity = batch
    return e


def test_for_engines_rejects_indivisible_cache_len():
    with pytest.raises(ValueError, match="divisible"):
        KVArena.for_engines([_fake_engine(cache_len=30)], block_tokens=16)


def test_for_engines_requires_a_paged_engine():
    with pytest.raises(ValueError, match="paged-capable"):
        KVArena.for_engines([], block_tokens=16)


def test_for_engines_rejects_layer_or_dtype_mismatch():
    a = _fake_engine(shape=(1, 1, 32, 2, 8))
    with pytest.raises(ValueError, match="layer count"):
        KVArena.for_engines([a, _fake_engine(shape=(2, 1, 32, 2, 8))],
                            block_tokens=16)
    with pytest.raises(ValueError, match="dtype"):
        KVArena.for_engines([a, _fake_engine(dtype=jnp.float32)],
                            block_tokens=16)
    with pytest.raises(ValueError, match="leaf names"):
        KVArena.for_engines([a, _fake_engine(leaves=("k", "v", "ks"))],
                            block_tokens=16)


def test_for_engines_pads_tails_to_cohort_max():
    """Cohorts with different head geometry share one pool: pages carry
    the elementwise-max tail, each engine uses its leading corner."""
    a = _fake_engine(shape=(1, 1, 32, 2, 8))
    b = _fake_engine(shape=(1, 1, 32, 4, 4))
    arena = KVArena.for_engines([a, b], block_tokens=16, shrink=1.0)
    assert arena.buffers()["k"].shape[3:] == (4, 8)
    # 2 engines x batch 2 x (32/16 blocks) = 8 allocatable pages
    assert arena.total_pages == 8
    half = KVArena.for_engines([a, b], block_tokens=16, shrink=0.5)
    assert half.total_pages == 4


# -- admission-reservation arithmetic ----------------------------------------


def test_pages_for_admission_is_cap_aware():
    """The reservation checked at admission must equal the DISTINCT
    blocks the row can touch given its cap — prompt-prefix blocks plus
    the blocks under the write span [t, min(t+n, n_max)) — checked
    against an independent set-based oracle.  It must collapse to the
    old worst-case count only when the cap fills the remaining
    headroom, and shrink below it for short caps (the over-reservation
    this PR fixes)."""
    eng = tiny_engine("bloom-3b", batch_capacity=2, s_max=8, n_max=8)
    shrunk = False
    for bt in (4, 8):
        nb = eng.cache_len // bt
        npb = -(-eng.s_max // bt)
        assert eng.pages_for_admission(0, 0, bt) == 0       # cap-0 row
        assert eng.pages_for_admission(eng.n_max, 4, bt) == 0  # no headroom
        for t in range(eng.n_max):
            worst = eng.pages_for_admission(t, eng.n_max, bt)
            assert worst <= nb
            for n in range(1, eng.n_max + 1):
                span = range(t, min(t + n, eng.n_max))
                blocks = set(range(npb)) \
                    | {(eng.s_max + tau) // bt for tau in span}
                got = eng.pages_for_admission(t, n, bt)
                assert got == len(blocks), (bt, t, n)
                assert got <= worst
                shrunk |= got < worst
    # at bt=4 the write region spans 2 blocks, so short caps really do
    # reserve fewer pages than the worst case (at bt=8 it is one block)
    assert shrunk


# -- engine path: arena-backed generation is bit-identical to the slab -------


@pytest.fixture(scope="module")
def hetero_node():
    """Two cohorts with DIFFERENT head dims (80 vs 128 after reduction)
    sharing one padded-tail pool — the cross-cohort reuse case."""
    engines = {a: tiny_engine(a, batch_capacity=4, s_max=32, n_max=16)
               for a in ("bloom-3b", "bloom-7b1")}
    arena = KVArena.for_engines(engines, block_tokens=16)
    return engines, arena


def assert_same_generation(a, b):
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.lengths, b.lengths)
    assert a.batch == b.batch


@pytest.mark.parametrize("bits", [0, 8, 4])
def test_paged_engine_matches_slab_edge_cases(hetero_node, bits):
    """cap=0 rows, pad-token prompts, padding-only slots and all weight
    precisions: paged == slab == reference, and every lease comes back."""
    engines, arena = hetero_node
    eng = engines["bloom-3b"]
    prompts = [[1, 2, 3], [0, 0], [7]]          # slot 4 stays padding-only
    caps = [16, 0, 7]
    ref = eng.generate(prompts, n_tokens=caps, quant_bits=bits)
    for k in (1, 3, 16):
        free0 = arena.free_pages
        got = eng.generate_via_chunks(prompts, n_tokens=caps, k=k,
                                      quant_bits=bits, arena=arena)
        assert arena.free_pages == free0        # all leases returned
        assert_same_generation(got, ref)
    assert got.lengths[1] == 0                  # cap=0 row emits nothing


def test_paged_engine_matches_slab_across_cohorts(hetero_node):
    """The 128-head cohort writes the same pool the 80-head cohort uses
    (padded tails) — both must stay bit-identical to their slabs."""
    engines, arena = hetero_node
    for arch, eng in engines.items():
        prompts = [[4, 5, 6], [9]]
        ref = eng.generate(prompts, n_tokens=[6, 16])
        got = eng.generate_via_chunks(prompts, n_tokens=[6, 16], k=3,
                                      arena=arena)
        assert_same_generation(got, ref)
    assert arena.free_pages == arena.total_pages


def test_paged_engine_immediate_eos(hetero_node):
    """A row whose first sampled token is EOS emits exactly one token
    through the paged path too."""
    engines, arena = hetero_node
    eng = engines["bloom-3b"]
    ref = eng.generate_reference([[9, 8, 7]], n_tokens=[6])
    tok0 = int(ref.tokens[0, 0])
    eng2 = ServingEngine(eng.cfg, params=eng._raw_params,
                         batch_capacity=4, s_max=32, n_max=16, eos_id=tok0)
    got = eng2.generate_via_chunks([[9, 8, 7]], n_tokens=[6], k=3,
                                   arena=arena)
    assert_same_generation(got, eng2.generate([[9, 8, 7]], n_tokens=[6]))
    assert got.lengths[0] == 1
    assert got.tokens[0, 0] == tok0


def test_paged_engine_int8_kv_cache(hetero_node):
    """kv_bits=8 engines carry quantized value pages PLUS scale pages;
    the paged path must reproduce the slab's int8-KV decode bitwise."""
    cfg = reduced_cfg("qwen3-1.7b").scaled(kv_bits=8)
    eng = ServingEngine(cfg, batch_capacity=2, s_max=32, n_max=16)
    assert eng.paged_capable
    arena = KVArena.for_engines([eng], block_tokens=16)
    assert set(arena.buffers()) >= {"k", "v"}
    assert len(arena.buffers()) == 4            # + per-token scale leaves
    prompts = [[3, 1, 4, 1, 5], [9, 2]]
    ref = eng.generate(prompts, n_tokens=[16, 5])
    for k in (1, 16):
        got = eng.generate_via_chunks(prompts, n_tokens=[16, 5], k=k,
                                      arena=arena)
        assert_same_generation(got, ref)
    assert arena.free_pages == arena.total_pages


def test_paged_refill_matches_slab_refill(hetero_node):
    """Mid-cohort refill into a freed slot: the paged splice (scatter +
    lease swap + ZERO-mapped junk gap) must reproduce the slab splice
    bit-for-bit, and the ZERO page must still be all-zero afterwards."""
    engines, arena = hetero_node
    eng = engines["bloom-3b"]
    prompts = [[1, 2, 3], [4, 5]]

    def drive(paged):
        st = eng.start_chunked(prompts, n_tokens=[16, 2],
                               arena=arena if paged else None)
        st = eng.generate_chunked(st, 3)        # row 1 (cap 2) finishes
        _, lengths, done, t = eng.poll_chunked(st)
        assert lengths[1] == 2
        st = eng.refill_chunked(st, [1], [[9, 9, 9]], [8], t_now=t)
        while True:
            st = eng.generate_chunked(st, 2)
            out, lengths, done, t = eng.poll_chunked(st)
            if eng.exhausted(lengths, done, st.caps_host, t):
                break
        if paged:
            eng.release_all(st)
        return out, lengths

    slab_out, slab_len = drive(paged=False)
    free0 = arena.free_pages
    paged_out, paged_len = drive(paged=True)
    np.testing.assert_array_equal(paged_out, slab_out)
    np.testing.assert_array_equal(paged_len, slab_len)
    assert arena.free_pages == free0
    for leaf in arena.buffers().values():       # ZERO page never written
        assert not np.asarray(leaf[:, ZERO_PAGE]).any()


# -- continuous executor: per-block admission + lease lifecycle --------------


def _node(batch=4, s_max=16, n_max=8, archs=("bloom-3b", "bloom-7b1")):
    return {a: tiny_engine(a, batch_capacity=batch, s_max=s_max,
                           n_max=n_max) for a in archs}


def test_executor_gates_admission_on_free_pages():
    """With slots free but pages short, ``accepts`` must refuse — and
    pending reservations from ``place`` count against later admissions
    within the same boundary."""
    engines = _node(batch=2, s_max=8, n_max=8, archs=("bloom-3b",))
    arena = KVArena.for_engines(engines, block_tokens=8, shrink=0.5)
    eng = engines["bloom-3b"]
    need = eng.pages_for_admission(0, 4, 8)     # r1's span: nb = 16/8 = 2
    assert arena.total_pages == need            # room for exactly one row
    menv = MultiLLMEnv.host({"bloom-3b": paper_env("bloom-3b", "W8A16")})
    ex = EngineContinuousExecutor(engines, seed=0, arena=arena)
    ex.bind(menv)
    r1 = Request(rid=0, s=2, n=4, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-3b")
    r2 = Request(rid=1, s=2, n=4, tau=50.0, a=0.0, h=1.0,
                 model_id="bloom-3b")
    assert ex.accepts("bloom-3b", r1)
    ex.place("bloom-3b", r1)
    # a slot is still free, but the page reservation is spoken for
    assert ex.node_headroom("bloom-3b") == eng.n_max
    assert not ex.accepts("bloom-3b", r2)


def test_executor_e2e_conservation_and_lease_drain():
    """Full ContinuousRuntime over a shared arena: request conservation,
    every page back on the free list after the drain, and the block
    metrics populated (occupancy from real pages, fragmentation from
    the junk-gap accounting)."""
    engines = _node()
    arena = KVArena.for_engines(engines, block_tokens=8)
    menv = MultiLLMEnv.host({m: paper_env(m, "W8A16") for m in engines})
    ex = EngineContinuousExecutor(engines, seed=0, arena=arena)
    tagger = random_tagger(sorted(menv.envs), seed=3)
    m = ContinuousRuntime(menv, "multi-dftsp", ex, k=2).run(
        gen=RequestGenerator(rate=6, seed=0, lengths=(2, 4, 8)),
        n_epochs=3, seed=0, warmup_epochs=0, tag_arrivals=tagger)
    assert m.arrived == m.served + m.dropped + len(m.final_queue_rids)
    assert m.served > 0
    assert arena.free_pages == arena.total_pages    # no leaked leases
    assert arena.alloc_peak > 0
    assert m.kv_alloc_tokens > 0
    assert 0 < m.mean_block_occupancy <= 1
    assert 0 <= m.fragmentation < 1
    assert all(t.kv_blocks_total == arena.total_pages
               for t in m.traces if t.kv_blocks_in_use)


def test_executor_slab_fallback_block_usage():
    """Without an arena the executor reports slot-level block usage —
    the same accounting interface, so the metrics stay comparable."""
    engines = _node(archs=("bloom-3b",))
    menv = MultiLLMEnv.host({"bloom-3b": paper_env("bloom-3b", "W8A16")})
    ex = EngineContinuousExecutor(engines, seed=0)
    ex.bind(menv)
    used, total, live, alloc = ex.block_usage()
    assert used == 0 and total == sum(e.batch_capacity
                                      for e in engines.values())
    assert live == alloc == 0
