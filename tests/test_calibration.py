"""Measured-beta calibration: the engine-feedback loop into quant=auto.

The flip pin reads the COMMITTED ``experiments/benchmarks/
calibration_flip.json`` artifact: the saved ``measure_beta`` record (plus
``attach_alphas``) fully determines the measured method set, so the
scheduler decisions are re-derived deterministically — no re-timing —
and the artifact's recorded flips must reproduce forever."""
from __future__ import annotations

import dataclasses
import json
import os

import pytest

from repro.config import get_arch
from repro.core.dftsp import dftsp_schedule_auto
from repro.core.environment import paper_env
from repro.core.policy import DftspPolicy
from repro.core.quantization import METHODS, candidate_methods
from repro.quant.calibration import measured_methods

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "benchmarks", "calibration_flip.json")


def _parity_record(alpha_w8=0.52, alpha_w4=0.27):
    """A measured record on a backend where quantization does NOT pay:
    every method times at fp parity (exactly what CPU interpret mode
    measures, since the engine dequantizes at load there)."""
    rec = {"methods": {}}
    for name, m in METHODS.items():
        meas = {"beta": 1.0}
        if m.weight_bits == 8:
            meas["alpha_w"] = alpha_w8
        elif m.weight_bits == 4:
            meas["alpha_w"] = alpha_w4
        rec["methods"][name] = meas
    return rec


def test_measured_methods_overrides():
    ms = measured_methods(_parity_record())
    assert set(ms) == set(METHODS)
    for name, m in ms.items():
        assert m.beta == 1.0
        if METHODS[name].weight_bits < 16:
            # engine KV/activations stay fp for weight-quant methods
            assert m.alpha_a == 1.0
    assert ms["W8A16"].alpha_w == pytest.approx(0.52)
    assert ms["W4A16-GPTQ"].alpha_w == pytest.approx(0.27)
    # the frozen Table-II records are untouched
    assert METHODS["W8A8"].beta == 0.7
    assert METHODS["W8A8"].alpha_a == 0.5


def test_beta_snap_grid():
    rec = _parity_record()
    rec["methods"]["W8A8"]["beta"] = 1.07     # timing noise around parity
    rec["methods"]["W8A16"]["beta"] = 0.94
    ms = measured_methods(rec, round_to=0.25)
    assert ms["W8A8"].beta == 1.0
    assert ms["W8A16"].beta == 1.0
    assert measured_methods(rec, round_to=0)["W8A8"].beta == \
        pytest.approx(1.07)


def test_parity_betas_prune_w8a8():
    """At measured parity, W8A16 Pareto-dominates W8A8 (same alpha/beta,
    strictly better dPPL) — W8A8 leaves the candidate set, while under
    Table II it is the FIRST candidate (lowest beta)."""
    ms = measured_methods(_parity_record())
    t2 = candidate_methods("bloom-3b")
    meas = candidate_methods("bloom-3b", methods=list(ms.values()))
    assert t2[0].name == "W8A8"
    assert "W8A8" not in {m.name for m in meas}
    assert meas[0].name == "W16A16"           # beta tie -> best dPPL first


def test_pinned_calibration_flip_artifact():
    """Re-derive both quant=auto decisions from the committed record and
    pin that the measured coefficients change them."""
    with open(ARTIFACT) as fh:
        art = json.load(fh)
    from benchmarks.calibration_flip import make_queue
    measured = measured_methods(art["meta"]["record"])
    for name, beta in art["meta"]["snapped_betas"].items():
        assert measured[name].beta == beta
    env = paper_env(art["meta"]["arch"], "W8A16")
    flips = 0
    for row in art["rows"]:
        qseed, _, t2_name, _, m_name, _, flipped = row
        queue = make_queue(qseed)
        _, m_t2, _ = dftsp_schedule_auto(env, queue)
        _, m_meas, _ = dftsp_schedule_auto(env, queue,
                                           methods=list(measured.values()))
        assert m_t2.name == t2_name
        assert m_meas.name == m_name
        assert (m_t2.name != m_meas.name) == bool(flipped)
        flips += bool(flipped)
    assert flips >= 1                          # the calibration is not a no-op


def test_policy_calib_measured():
    env = paper_env("bloom-3b", "W8A16")
    from benchmarks.calibration_flip import make_queue
    queue = make_queue(0)
    pol = DftspPolicy(quant="auto", calib="measured")
    with pytest.raises(RuntimeError):
        pol.select_quant(env, None, queue)
    pol.install_measured(measured_methods(_parity_record()))
    m = pol.select_quant(env, None, queue)
    assert m.name != "W8A8"
    t2 = DftspPolicy(quant="auto").select_quant(env, None, queue)
    assert t2.name == "W8A8"
    with pytest.raises(ValueError):
        DftspPolicy(calib="nope")


def test_serve_bits():
    assert METHODS["W8A16"].serve_bits == 8
    assert METHODS["W8A8"].serve_bits == (8, 8)
    assert METHODS["W16A16"].serve_bits == 16
    assert METHODS["W4A16-GPTQ"].serve_bits == 4


def test_measure_beta_smoke():
    """Structure + sanity of a real (tiny) engine measurement."""
    from repro.quant.calibration import attach_alphas, measure_beta
    from repro.serving.engine import ServingEngine
    cfg = get_arch("bloom-3b").scaled(n_layers=1, d_model=64, n_heads=2,
                                      n_kv_heads=2, d_ff=128, vocab=256)
    eng = ServingEngine(cfg, batch_capacity=2, s_max=8, n_max=8,
                        eos_id=-1, seed=0)
    rec = measure_beta(eng, methods=[METHODS["W8A16"]], batches=(2,),
                       iters=1, n_tokens=4, prompt_len=4)
    attach_alphas(rec, eng._raw_params)
    m = rec["methods"]["W8A16"]
    assert m["beta"] > 0 and m["beta"] == m["per_batch"]["2"]
    assert 0 < m["alpha_w"] < 1
    assert rec["arch"] == cfg.arch_id
    ms = measured_methods(rec)
    assert set(ms) == {"W8A16"}
    assert dataclasses.is_dataclass(ms["W8A16"])
