"""Multi-LLM edge node (paper §II's multi-model remark, beyond-paper)."""
from __future__ import annotations

import pytest

from repro.core import comm, problem
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, multi_dftsp, tag
from repro.core.request import RequestGenerator


def make_menv():
    return MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })


def make_pool(seed=0, rate=20):
    gen = RequestGenerator(rate=rate, seed=seed)
    reqs = gen.within(0, 2.0)
    half = len(reqs) // 2
    return tag(reqs[:half], "bloom-3b") + tag(reqs[half:], "bloom-7b1")


def test_schedules_both_models():
    sched, stats = multi_dftsp(make_menv(), make_pool(seed=1, rate=40))
    assert stats.z_solved == sum(len(v) for v in sched.values())
    assert stats.z_solved > 0


def test_shared_bandwidth_respected():
    menv = make_menv()
    sched, _ = multi_dftsp(menv, make_pool(seed=2, rate=60))
    all_sel = [r for v in sched.values() for r in v]
    env = menv.envs["bloom-3b"]
    assert sum(comm.rho_min_up(env, r) for r in all_sel) <= 1.0 + 1e-9
    assert sum(comm.rho_min_down(env, r) for r in all_sel) <= 1.0 + 1e-9


def test_shared_memory_respected():
    menv = make_menv()
    sched, _ = multi_dftsp(menv, make_pool(seed=3, rate=60))
    used = menv.weight_bytes()
    for mid, batch in sched.items():
        env = menv.envs[mid]
        cm = env.cost_model()
        used += env.quant.alpha_a * (
            cm.kv_bytes_prefill(env.s_max, len(batch))
            + cm.kv_bytes_decode([r.n for r in batch], env.s_max))
    assert used <= menv.M + 1e-6


def test_per_model_batches_meet_deadlines():
    menv = make_menv()
    sched, _ = multi_dftsp(menv, make_pool(seed=4, rate=40))
    t_queued = 0.0
    for mid in sorted(menv.envs,
                      key=lambda m: menv.envs[m].cost_model().weight_bytes()):
        batch = sched[mid]
        env = menv.envs[mid]
        if batch:
            t = problem.batch_compute_time(env, batch)
            for r in batch:
                assert r.t_w + env.T_U + t_queued + t + env.T_D \
                    <= r.tau + 1e-9
            t_queued += t


def test_requests_only_on_their_model():
    sched, _ = multi_dftsp(make_menv(), make_pool(seed=5))
    for mid, batch in sched.items():
        assert all(r.model_id == mid for r in batch)
