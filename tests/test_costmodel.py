"""Cost-model fidelity: the paper's §II-B equations reproduced exactly for
MHA dense archs, and the per-family generalizations' invariants."""
from __future__ import annotations

import pytest

from repro.config import get_arch
from repro.core.costmodel import PARAM_BYTES, CostModel


def paper_m1(c):
    return c.n_layers * (8 * c.d_model * c.d_head * c.n_heads
                         + 4 * c.d_model * c.d_ff)


def paper_t_prefill_flops(c, s, batch):
    return batch * c.n_layers * (6 * s * c.d_model ** 2
                                 + 4 * s * s * c.d_model
                                 + 2 * s * c.d_model ** 2
                                 + 4 * s * c.d_model * c.d_ff)


def paper_t_decode_flops(c, s, ns):
    total = 0.0
    for n in ns:
        total += c.n_layers * (n - 1) * (
            6 * c.d_model ** 2 + 4 * (s + n / 2) * c.d_model
            + 2 * c.d_model ** 2 + 4 * c.d_model * c.d_ff)
    return total


@pytest.mark.parametrize("arch", ["bloom-3b", "bloom-7b1", "opt-13b"])
def test_paper_equations_exact_for_mha_dense(arch):
    c = get_arch(arch)
    cm = CostModel(c, paper_faithful=True)
    assert cm.weight_bytes() == pytest.approx(paper_m1(c))
    assert cm.prefill_flops(512, 4) == pytest.approx(
        paper_t_prefill_flops(c, 512, 4))
    assert cm.decode_flops(512, [128, 256]) == pytest.approx(
        paper_t_decode_flops(c, 512, [128, 256]))


@pytest.mark.parametrize("arch", ["bloom-3b", "opt-13b"])
def test_paper_kv_cache_equations(arch):
    c = get_arch(arch)
    cm = CostModel(c, paper_faithful=True)
    # m2_I = 4 L s' dm * batch   (2 bytes x (K+V) = 4)
    assert cm.kv_bytes_prefill(512, 3) == pytest.approx(
        4 * c.n_layers * 512 * c.n_kv_heads * c.d_head * 3)
    # m2_A = 4 L n dm
    assert cm.kv_bytes_decode([256]) == pytest.approx(
        4 * c.n_layers * 256 * c.n_kv_heads * c.d_head)


def test_gqa_cache_smaller_than_mha():
    c = get_arch("qwen3-1.7b")           # 16 q heads, 8 kv heads
    cm = CostModel(c)
    mha = CostModel(c.scaled(n_kv_heads=c.n_heads))
    assert cm.kv_bytes_prefill(512, 1) == pytest.approx(
        mha.kv_bytes_prefill(512, 1) * c.n_kv_heads / c.n_heads)


def test_ssm_decode_memory_is_context_free():
    c = get_arch("xlstm-1.3b")
    cm = CostModel(c)
    assert cm.kv_bytes_decode([128]) == 0.0
    assert cm.state_bytes() > 0
    # prefill footprint must not grow with s
    assert cm.kv_bytes_prefill(512, 1) == cm.kv_bytes_prefill(32768, 1)


def test_ssm_decode_flops_linear_in_n():
    cm = CostModel(get_arch("xlstm-1.3b"))
    f1 = cm.decode_flops(512, [101])
    f2 = cm.decode_flops(512, [201])
    # (n-1) scaling exactly linear (no quadratic attention-read term)
    assert f2 / f1 == pytest.approx(200 / 100, rel=1e-6)
    assert not cm.latency_is_quadratic()


def test_dense_decode_flops_superlinear_in_n():
    cm = CostModel(get_arch("olmo-1b"))
    f1 = cm.decode_flops(512, [101])
    f2 = cm.decode_flops(512, [201])
    assert f2 > 2.0 * f1
    assert cm.latency_is_quadratic()


def test_sliding_window_caps_cache():
    c = get_arch("mixtral-8x22b")        # SWA 4096
    cm = CostModel(c)
    assert c.sliding_window == 4096
    assert cm.kv_bytes_prefill(32768, 1) == cm.kv_bytes_prefill(4096, 1)
    # decode from a full-window prompt adds nothing
    assert cm.kv_bytes_decode([256], s=8192) == 0.0


def test_moe_flops_count_active_only():
    c = get_arch("mixtral-8x22b")
    cm = CostModel(c)
    dense_equiv = CostModel(c.scaled(
        moe=type(c.moe)(n_experts=0, top_k=0)))
    # top-2-of-8 FFN ~= 2x the dense FFN cost (+ router), never 8x
    assert cm._ffn_flops_per_token() < 2.1 * dense_equiv._ffn_flops_per_token()
    assert cm._ffn_flops_per_token() > 1.9 * dense_equiv._ffn_flops_per_token()


def test_moe_weights_count_all_experts():
    c = get_arch("granite-moe-1b-a400m")
    assert c.param_count() > 3 * c.active_param_count()


def test_hybrid_cache_counts_shared_sites_only():
    c = get_arch("zamba2-7b")
    cm = CostModel(c)
    n_sites = c.n_layers // c.hybrid.attn_every
    per_tok = 2 * PARAM_BYTES * n_sites * c.n_kv_heads * c.d_head
    assert cm._kv_bytes_per_token() == pytest.approx(per_tok)


def test_encdec_prefill_includes_encoder():
    c = get_arch("whisper-tiny")
    cm = CostModel(c)
    dec_only = CostModel(c.scaled(encdec=None, family="dense"))
    assert cm.prefill_flops(64, 1) > dec_only.prefill_flops(64, 1)
