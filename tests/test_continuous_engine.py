"""Chunked (re-entrant) decode: the continuous-batching data plane.

Contract: ``start_chunked`` + ``generate_chunked(state, k)`` driven to
completion is BIT-IDENTICAL to the single fused loop (``generate``) and
to the legacy host loop (``generate_reference``) for every chunk size k —
including every edge case the fused loop is tested against (cap=0 rows,
immediate EOS, padding-only rows, empty batch, quant bits 0/8/4).  On top
of the frozen-batch contract, ``refill_chunked`` splices new prompts into
slots freed mid-cohort without perturbing live rows.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.serving.engine import ServingEngine

CHUNKS = [1, 3, 8]          # 8 == n_max of the module engine (k = max)


def assert_same_generation(a, b):
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.lengths, b.lengths)
    assert a.batch == b.batch


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_cfg("bloom-3b")
    return ServingEngine(cfg, batch_capacity=4, s_max=32, n_max=8)


# -- equivalence: chunked == fused == reference, for every k -----------------


@pytest.mark.parametrize("k", CHUNKS)
def test_chunked_matches_fused_and_reference_edge_cases(engine, k):
    """cap=0 rows, pad-token prompts and padding-only rows decode
    bit-identically through chunked segments of any size."""
    prompts = [[1, 2, 3], [0, 0], [7]]       # slot 4 stays padding-only
    caps = [5, 0, 8]
    chunked = engine.generate_via_chunks(prompts, n_tokens=caps, k=k)
    assert_same_generation(chunked, engine.generate(prompts, n_tokens=caps))
    assert_same_generation(chunked,
                           engine.generate_reference(prompts, n_tokens=caps))
    assert chunked.lengths[1] == 0           # cap=0 row emits nothing
    assert np.all(chunked.tokens[1] == 0)


@pytest.mark.parametrize("k", CHUNKS)
def test_chunked_matches_fused_empty_batch(engine, k):
    a = engine.generate_via_chunks([], n_tokens=[], k=k)
    b = engine.generate([], n_tokens=[])
    assert_same_generation(a, b)
    assert a.tokens.shape == (0, engine.n_max)


@pytest.mark.parametrize("bits", [0, 8, 4])
@pytest.mark.parametrize("k", [1, 3])
def test_chunked_matches_reference_all_precisions(engine, bits, k):
    prompts = [[5, 6, 7], [1, 2], [9, 9, 9, 9]]
    a = engine.generate_via_chunks(prompts, n_tokens=[8, 3, 6], k=k,
                                   quant_bits=bits)
    b = engine.generate_reference(prompts, n_tokens=[8, 3, 6],
                                  quant_bits=bits)
    assert_same_generation(a, b)
    assert a.lengths.max() >= 1


@pytest.mark.parametrize("k", CHUNKS)
def test_chunked_immediate_eos(engine, k):
    """A row whose FIRST sampled token is EOS emits exactly one token
    through any segmentation."""
    ref = engine.generate_reference([[9, 8, 7]], n_tokens=[6])
    tok0 = int(ref.tokens[0, 0])
    eng2 = ServingEngine(engine.cfg, params=engine._raw_params,
                         batch_capacity=4, s_max=32, n_max=8, eos_id=tok0)
    a = eng2.generate_via_chunks([[9, 8, 7]], n_tokens=[6], k=k)
    assert_same_generation(a, eng2.generate_reference([[9, 8, 7]],
                                                      n_tokens=[6]))
    assert a.lengths[0] == 1
    assert a.tokens[0, 0] == tok0


def test_chunked_state_reentry_any_split(engine):
    """Segments of mixed sizes resume exactly where the cohort left off:
    2+3+max == one max-size segment."""
    prompts = [[1, 2, 3], [4, 5, 6]]
    caps = [8, 8]
    one = engine.generate_chunked(
        engine.start_chunked(prompts, caps), engine.n_max)
    mixed = engine.start_chunked(prompts, caps)
    for k in (2, 3, engine.n_max):
        mixed = engine.generate_chunked(mixed, k)
    a, b = engine.poll_chunked(one), engine.poll_chunked(mixed)
    np.testing.assert_array_equal(a[0], b[0])        # out
    np.testing.assert_array_equal(a[1], b[1])        # lengths
    np.testing.assert_array_equal(a[2], b[2])        # done
    assert a[3] == b[3]                              # t


def test_chunked_transfer_counts(engine, monkeypatch):
    """k=max chunked decode costs the SAME two transfers as the fused
    loop (one device_put at start, one device_get at poll); smaller k
    pays one poll device_get per segment — the price of the admission
    point."""
    counts = {"get": 0, "put": 0}
    real_get, real_put = jax.device_get, jax.device_put

    def counting_get(x):
        counts["get"] += 1
        return real_get(x)

    def counting_put(x):
        counts["put"] += 1
        return real_put(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    monkeypatch.setattr(jax, "device_put", counting_put)

    engine.generate_via_chunks([[1, 2, 3], [4, 5, 6]], n_tokens=[5, 5],
                               k=engine.n_max)
    assert counts == {"get": 1, "put": 1}

    counts.update(get=0, put=0)
    res = engine.generate_via_chunks([[1, 2, 3], [4, 5, 6]],
                                     n_tokens=[5, 5], k=1)
    assert counts["put"] == 1
    # one poll per 1-token segment; the last segment's poll observes the
    # cap-limited exhaustion, so polls == decode steps
    assert int(res.lengths.max()) == 5          # cap-limited, no early EOS
    assert counts["get"] == 5


def test_poll_without_tokens_skips_the_big_buffer(engine):
    """The per-segment hot path polls only (lengths, done, t); the
    (B, n_max) token buffer stays on device until someone asks."""
    state = engine.start_chunked([[1, 2, 3]], n_tokens=[4])
    state = engine.generate_chunked(state, 2)
    out, lengths, done, t = engine.poll_chunked(state, with_tokens=False)
    assert out is None
    full, lengths2, done2, t2 = engine.poll_chunked(state)
    assert full.shape == (engine.batch_capacity, engine.n_max)
    np.testing.assert_array_equal(lengths, lengths2)
    np.testing.assert_array_equal(done, done2)
    assert t == t2


# -- slot eviction / refill ---------------------------------------------------


def test_refill_leaves_live_rows_untouched(engine):
    """Refilling a freed slot mid-cohort must not perturb rows that are
    still decoding: their tokens stay bit-identical to an undisturbed
    run of the same batch."""
    prompts = [[1, 2, 3], [4, 5]]
    undisturbed = engine.generate(prompts, n_tokens=[8, 2])

    state = engine.start_chunked(prompts, n_tokens=[8, 2])
    state = engine.generate_chunked(state, 3)        # row 1 (cap 2) is done
    _, lengths, done, t = engine.poll_chunked(state)
    assert lengths[1] == 2
    state = engine.refill_chunked(state, [1], [[9, 9, 9]], [4], t_now=t)
    while True:
        state = engine.generate_chunked(state, 2)
        out, lengths, done, t = engine.poll_chunked(state)
        if engine.exhausted(lengths, done, state.caps_host, t):
            break
    np.testing.assert_array_equal(out[0], undisturbed.tokens[0])
    assert lengths[0] == undisturbed.lengths[0]
    assert 1 <= lengths[1] <= 4                      # refilled row decoded


def test_refill_caps_clamp_to_cohort_headroom(engine):
    """A row admitted at cohort step t can emit at most n_max - t tokens
    (its cache writes must fit the static capacity); refill_chunked
    clamps the cap and caps_host mirrors it."""
    state = engine.start_chunked([[1, 2, 3]], n_tokens=[8])
    state = engine.generate_chunked(state, 5)
    _, _, _, t = engine.poll_chunked(state)
    assert engine.headroom(t) == engine.n_max - t
    state = engine.refill_chunked(state, [3], [[7, 7]], [8], t_now=t)
    assert state.caps_host[3] == engine.n_max - t
    while True:
        state = engine.generate_chunked(state, 4)
        out, lengths, done, t = engine.poll_chunked(state)
        if engine.exhausted(lengths, done, state.caps_host, t):
            break
    assert t <= engine.n_max
    assert lengths[3] <= state.caps_host[3]


def test_refill_cap_max_tightens_headroom_clamp(engine):
    """An executor-supplied ``cap_max`` binds below the cohort's own
    headroom; caps_host mirrors the clamped value."""
    state = engine.start_chunked([[1, 2, 3]], n_tokens=[8])
    state = engine.generate_chunked(state, 2)
    _, _, _, t = engine.poll_chunked(state)
    assert engine.headroom(t) > 1
    state = engine.refill_chunked(state, [2], [[5, 5]], [8], t_now=t,
                                  cap_max=1)
    assert state.caps_host[2] == 1
    # and a cap_max looser than the cohort's own headroom changes nothing
    state2 = engine.refill_chunked(state, [3], [[6]], [8], t_now=t,
                                   cap_max=engine.n_max * 2)
    assert state2.caps_host[3] == min(8, engine.headroom(t))


def test_refill_cap_max_zero_is_noop(engine):
    """``cap_max=0`` (or a fully exhausted cohort window) must leave the
    state UNTOUCHED — no slot splice, no cap update, same object back.
    Regression: the historical path spliced a zero-cap row in, burning
    the slot on a request that could never emit."""
    state = engine.start_chunked([[1, 2, 3]], n_tokens=[8])
    state = engine.generate_chunked(state, 2)
    _, _, _, t = engine.poll_chunked(state)
    before = np.asarray(state.caps_host).copy()
    out = engine.refill_chunked(state, [2], [[5, 5]], [8], t_now=t,
                                cap_max=0)
    assert out is state
    assert np.array_equal(np.asarray(out.caps_host), before)
    # empty slot list short-circuits the same way
    assert engine.refill_chunked(state, [], [], [], t_now=t) is state


# -- multi-engine pool: interleaved cohorts stay bit-identical ----------------


def test_two_engine_pool_chunked_bit_identical_k1_vs_kmax():
    """The multi-engine slot pool drives one cohort PER ENGINE on the
    node's shared segment grid.  Interleaving the engines' chunked
    segments must not perturb either cohort: k=1 and k=n_max produce
    bit-identical per-request token outputs for each model, equal to
    each engine's one-shot fused ``generate``."""
    engines = {arch: ServingEngine(reduced_cfg(arch), batch_capacity=4,
                                   s_max=16, n_max=8)
               for arch in ("bloom-3b", "bloom-7b1")}
    prompts = {"bloom-3b": [[1, 2, 3], [7, 7]],
               "bloom-7b1": [[4, 5, 6], [9]]}
    caps = {"bloom-3b": [8, 5], "bloom-7b1": [6, 8]}

    def drive(k):
        """Advance every live cohort by one k-segment per round — the
        executor's lock-step grid."""
        live = {m: engines[m].start_chunked(prompts[m], caps[m])
                for m in engines}
        out = {}
        while live:
            for m in list(live):
                eng = engines[m]
                st = eng.generate_chunked(live[m], k)
                o, lengths, done, t = eng.poll_chunked(st)
                live[m] = st
                if eng.exhausted(lengths, done, st.caps_host, t):
                    out[m] = (o, lengths)
                    del live[m]
        return out

    fine, coarse = drive(1), drive(8)
    for m, eng in engines.items():
        np.testing.assert_array_equal(fine[m][0], coarse[m][0])
        np.testing.assert_array_equal(fine[m][1], coarse[m][1])
        fused = eng.generate(prompts[m], n_tokens=caps[m])
        nb = len(prompts[m])
        np.testing.assert_array_equal(fine[m][0][:nb], fused.tokens)
        np.testing.assert_array_equal(fine[m][1][:nb], fused.lengths)


def test_refill_recurrent_family_matches_solo_decode():
    """Recurrent-state families carry no junk-attention positions, so a
    refilled row must decode bit-identically to serving its prompt
    alone."""
    eng = ServingEngine(reduced_cfg("xlstm-1.3b"), batch_capacity=2,
                        s_max=16, n_max=4)
    state = eng.start_chunked([[1, 2, 3]], n_tokens=[2])
    state = eng.generate_chunked(state, 2)
    _, _, _, t = eng.poll_chunked(state)
    state = eng.refill_chunked(state, [1], [[7, 8]], [2], t_now=t)
    state = eng.generate_chunked(state, eng.n_max)
    out, lengths, _, _ = eng.poll_chunked(state)
    solo = eng.generate([[7, 8]], n_tokens=[2])
    np.testing.assert_array_equal(out[1, :2], solo.tokens[0, :2])
    assert lengths[1] == solo.lengths[0]


def test_cache_batch_axes_derived_per_family():
    """The refill merge finds each cache leaf's batch axis structurally —
    the axes tree mirrors the cache tree exactly, with a valid axis per
    leaf, for attention AND recurrent-state families."""
    for arch in ("bloom-3b", "xlstm-1.3b", "zamba2-7b"):
        eng = ServingEngine(reduced_cfg(arch), batch_capacity=2,
                            s_max=16, n_max=4)
        axes = eng._cache_batch_axes()
        shapes = jax.eval_shape(lambda e=eng: e.model.init_cache(
            2, e.cache_len))
        assert jax.tree_util.tree_structure(axes) == \
            jax.tree_util.tree_structure(shapes)
        for ax, leaf in zip(jax.tree_util.tree_leaves(axes),
                            jax.tree_util.tree_leaves(shapes)):
            assert 0 <= ax < len(leaf.shape)
            assert leaf.shape[ax] == 2        # the batch dim
    eng_t = ServingEngine(reduced_cfg("bloom-3b"), batch_capacity=2,
                          s_max=16, n_max=4)
    assert set(jax.tree_util.tree_leaves(eng_t._cache_batch_axes())) \
        == {1}                                 # (L, B, W, nkv, dh)
