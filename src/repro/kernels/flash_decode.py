"""Pallas TPU flash-decoding kernel for the auto-regressive stage.

The paper's t_A (decode latency) is memory-bound: one token's query reads
the whole KV cache.  TPU-native design (DESIGN.md §3): the cache streams
HBM->VMEM in (block_s, dh) tiles; an online-softmax accumulator (running
max m, denominator l, weighted sum acc) lives in VMEM scratch across the
sequence-block grid steps, so each KV byte is read exactly once.  GQA
grouping puts the G = nh/nkv query heads of one KV head together in the
tile so the MXU sees (G, dh) x (dh, block_s) matmuls.

Grid: (B, nkv, W/block_s), sequence innermost ("arbitrary").  The slot
mask (slot < n_valid) handles both partially-filled caches and the rolling
sliding-window layout (validity is a count, order is irrelevant under
softmax since rope was applied before caching).

PAGED variant (``flash_decode_paged``, DESIGN.md §2.3): K/V live in a
node-wide block-pool arena of fixed ``block_tokens`` pages instead of one
contiguous (B, W) slab.  The grid still walks LOGICAL sequence blocks;
the physical page holding logical block j of row b is resolved per grid
step through a scalar-prefetched block table — the index map reads
``table[b, j]`` and the pipeline DMAs that page, so the kernel body is
byte-for-byte the contiguous kernel with ``block_s = block_tokens``.
Driven with a logical-order table over the same values it is therefore
bit-identical to ``flash_decode`` at the same block size (the oracle the
paged tests pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG = -1e30


def _decode_kernel(nv_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_s: int, block_s: int):
    """One (batch, kv-head) pair; grid axis 2 walks the sequence blocks.

    q_ref:  (1, 1, G, dh)   queries for this kv head's group
    k_ref:  (1, block_s, 1, dh)
    v_ref:  (1, block_s, 1, dh)
    nv_ref: (B,) int32      valid-slot counts (scalar-prefetch, SMEM);
                            indexed by the batch grid position
    o_ref:  (1, 1, G, dh)
    scratch: m/l (G, 128), acc (G, dh)  [f32]
    """
    ss = pl.program_id(2)

    @pl.when(ss == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / (dh ** 0.5))   # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)                       # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)                       # (bs, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, bs)
    slot = ss * block_s + jax.lax.broadcasted_iota(jnp.int32, (G, block_s), 1)
    s = jnp.where(slot < nv_ref[pl.program_id(0)], s, NEG)

    m_prev = m_ref[:, :1]                                        # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)                   # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                       # (G, bs)
    alpha = jnp.exp(m_prev - m_new)                              # (G, 1)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ss == n_s - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 n_valid: jax.Array, *, block_s: int = DEFAULT_BS,
                 interpret: bool = False) -> jax.Array:
    """GQA decode attention.  q: (B, nh, dh); k/v: (B, W, nkv, dh);
    n_valid: scalar or (B,) valid-slot count.  Returns (B, nh, dh)."""
    B, nh, dh = q.shape
    W, nkv = k.shape[1], k.shape[2]
    G = nh // nkv
    block_s = min(block_s, W)
    assert W % block_s == 0, (W, block_s)
    n_s = W // block_s
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (B,))

    qg = q.reshape(B, nkv, G, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, s, nv: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, nv: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, nv: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, s, nv: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_s=n_s, block_s=block_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nv, qg, k, v)
    return out.reshape(B, nh, dh)


def _paged_decode_kernel(nv_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_b: int, block_t: int):
    """One (batch, kv-head) pair; grid axis 2 walks the LOGICAL blocks of
    the row's block table.  The page indirection happened in the BlockSpec
    index map (``tbl_ref[b, j]``), so k_ref/v_ref already hold the right
    physical page — the body is the contiguous kernel at block_s=block_t.

    q_ref:  (1, 1, G, dh)
    k_ref:  (1, block_t, 1, dh)   physical page, logical block j
    v_ref:  (1, block_t, 1, dh)
    nv_ref: (B,) int32            valid-slot counts (scalar prefetch)
    tbl_ref:(B, n_b) int32        block table (scalar prefetch)
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / (dh ** 0.5))
    k = k_ref[0, :, 0].astype(jnp.float32)                       # (bt, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, bt)
    slot = j * block_t + jax.lax.broadcasted_iota(jnp.int32, (G, block_t), 1)
    s = jnp.where(slot < nv_ref[pl.program_id(0)], s, NEG)

    m_prev = m_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_b - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       table: jax.Array, n_valid: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """GQA decode attention through a block table.

    q: (B, nh, dh); k_pages/v_pages: (P, block_tokens, nkv, dh) — the
    node-wide page arena; table: (B, n_b) int32, logical block j of row b
    lives in physical page ``table[b, j]``; n_valid: scalar or (B,) valid
    LOGICAL slot count.  Returns (B, nh, dh).
    """
    B, nh, dh = q.shape
    P, bt, nkv, _ = k_pages.shape
    n_b = table.shape[1]
    G = nh // nkv
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (B,))
    tbl = jnp.asarray(table, jnp.int32)

    qg = q.reshape(B, nkv, G, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh),
                         lambda b, h, j, nv, tbl: (b, h, 0, 0)),
            # page indirection: logical block j -> physical page tbl[b, j]
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, tbl: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, tbl: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda b, h, j, nv, tbl: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, n_b=n_b, block_t=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nv, tbl, qg, k_pages, v_pages)
    return out.reshape(B, nh, dh)
