"""Pallas TPU flash-decoding kernel for the auto-regressive stage.

The paper's t_A (decode latency) is memory-bound: one token's query reads
the whole KV cache.  TPU-native design (DESIGN.md §3): the cache streams
HBM->VMEM in (block_s, dh) tiles; an online-softmax accumulator (running
max m, denominator l, weighted sum acc) lives in VMEM scratch across the
sequence-block grid steps, so each KV byte is read exactly once.  GQA
grouping puts the G = nh/nkv query heads of one KV head together in the
tile so the MXU sees (G, dh) x (dh, block_s) matmuls.

Grid: (B, nkv, W/block_s), sequence innermost ("arbitrary").  The slot
mask (slot < n_valid) handles both partially-filled caches and the rolling
sliding-window layout (validity is a count, order is irrelevant under
softmax since rope was applied before caching).

PAGED variant (``flash_decode_paged``, DESIGN.md §2.3): K/V live in a
node-wide block-pool arena of fixed ``block_tokens`` pages instead of one
contiguous (B, W) slab.  The grid still walks LOGICAL sequence blocks;
the physical page holding logical block j of row b is resolved per grid
step through a scalar-prefetched block table — the index map reads
``table[b, j]`` and the pipeline DMAs that page, so the kernel body is
byte-for-byte the contiguous kernel with ``block_s = block_tokens``.
Driven with a logical-order table over the same values it is therefore
bit-identical to ``flash_decode`` at the same block size (the oracle the
paged tests pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG = -1e30


def _decode_kernel(nv_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_s: int, block_s: int):
    """One (batch, kv-head) pair; grid axis 2 walks the sequence blocks.

    q_ref:  (1, 1, G, dh)   queries for this kv head's group
    k_ref:  (1, block_s, 1, dh)
    v_ref:  (1, block_s, 1, dh)
    nv_ref: (B,) int32      valid-slot counts (scalar-prefetch, SMEM);
                            indexed by the batch grid position
    o_ref:  (1, 1, G, dh)
    scratch: m/l (G, 128), acc (G, dh)  [f32]
    """
    ss = pl.program_id(2)

    @pl.when(ss == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / (dh ** 0.5))   # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)                       # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)                       # (bs, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, bs)
    slot = ss * block_s + jax.lax.broadcasted_iota(jnp.int32, (G, block_s), 1)
    s = jnp.where(slot < nv_ref[pl.program_id(0)], s, NEG)

    m_prev = m_ref[:, :1]                                        # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)                   # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                       # (G, bs)
    alpha = jnp.exp(m_prev - m_new)                              # (G, 1)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ss == n_s - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 n_valid: jax.Array, *, block_s: int = DEFAULT_BS,
                 interpret: bool = False) -> jax.Array:
    """GQA decode attention.  q: (B, nh, dh); k/v: (B, W, nkv, dh);
    n_valid: scalar or (B,) valid-slot count.  Returns (B, nh, dh)."""
    B, nh, dh = q.shape
    W, nkv = k.shape[1], k.shape[2]
    G = nh // nkv
    block_s = min(block_s, W)
    assert W % block_s == 0, (W, block_s)
    n_s = W // block_s
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (B,))

    qg = q.reshape(B, nkv, G, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, s, nv: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, nv: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, nv: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, s, nv: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_s=n_s, block_s=block_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nv, qg, k, v)
    return out.reshape(B, nh, dh)


# ---------------------------------------------------------------------------
# Fused QUANTIZED flash-decode (DESIGN.md §3): the QKV/output projections
# consume int8 weight tiles directly inside the decode grid, so one kernel
# covers hidden-state -> attention output and the HBM side never sees an
# fp weight copy.  Layout per grid step (b, h, ss):
#
#   ss == 0      : project q/k1/v1 for (b, h) from x (1, D) and the int8
#                  tiles wq (D, G*dh) / wk, wv (D, dh); apply rope from
#                  precomputed cos/sin rows; stash in VMEM scratch and
#                  emit k1/v1 as outputs (the caller writes the cache —
#                  the kernel attends over the PRE-write cache and folds
#                  the current token in as a final online-softmax step,
#                  which is equivalent because slot pos is masked out of
#                  the pre-write reads).
#   every ss     : one online-softmax block over the cache, exactly
#                  ``_decode_kernel``.
#   ss == n_s-1  : fold in the current token, normalize, and project the
#                  (G, dh) head group through its wo tile (G*dh, D),
#                  accumulating into o (1, D) across the h grid steps
#                  (axis 1 is "arbitrary" so the output block stays
#                  resident in VMEM).
#
# ``a8=True`` additionally quantizes the projection activations per row
# (absmax/127, in-kernel) and runs int8 x int8 -> int32 dots — the W8A8
# tier inside the decode grid.  Attention itself stays f32 (the cache is
# fp here; int8-KV decode keeps its own dequant path in models/common).
# ---------------------------------------------------------------------------


def _qproject(xr, w, s, a8: bool):
    """(1, Din) f32 @ dequant(w (Din, Dout) int8, s (1, Dout)) -> (1, Dout).

    a8: dynamic rowwise activation quantization feeding an int8 x int8
    dot with int32 accumulation and a single rescale at writeout (the
    in-grid copy of the quant_matmul W8A8 tier)."""
    if a8:
        amax = jnp.max(jnp.abs(xr), axis=-1, keepdims=True)
        sx = jnp.where(amax > 0, amax * jnp.float32(1.0 / 127.0), 1.0)
        xq = jnp.clip(jnp.round(xr / sx), -128, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(xq, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * sx * s.astype(jnp.float32)
    wf = w.astype(jnp.float32) * s.astype(jnp.float32)
    return jnp.dot(xr, wf, preferred_element_type=jnp.float32)


def _rot_half(t, cos, sin):
    """Rope rotation on (R, dh) rows; cos/sin (1, dh/2) — the same
    split-halves convention as models/common.apply_rope."""
    h = t.shape[-1] // 2
    t1, t2 = t[:, :h], t[:, h:]
    return jnp.concatenate([t1 * cos - t2 * sin, t1 * sin + t2 * cos],
                           axis=-1)


def _fused_body(nv_ref, ev_ref, x_ref, cos_ref, sin_ref, wq_ref, sq_ref,
                wk_ref, sk_ref, wv_ref, sv_ref, wo_ref, so_ref, k_ref,
                v_ref, o_ref, k1_ref, v1_ref, q_s, k1_s, v1_s, m_ref,
                l_ref, acc_ref, *, n_s: int, block_s: int, use_rope: bool,
                a8: bool):
    """Shared body of the contiguous and paged fused kernels (the paged
    variant only changes how k_ref/v_ref blocks are addressed)."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    ss = pl.program_id(2)
    G, dh = q_s.shape
    inv_sqrt = 1.0 / (dh ** 0.5)

    @pl.when(ss == 0)
    def _():
        xr = x_ref[...].astype(jnp.float32)                      # (1, D)
        qh = _qproject(xr, wq_ref[...], sq_ref[...], a8).reshape(G, dh)
        k1 = _qproject(xr, wk_ref[...], sk_ref[...], a8)         # (1, dh)
        v1 = _qproject(xr, wv_ref[...], sv_ref[...], a8)
        if use_rope:
            cos, sin = cos_ref[...], sin_ref[...]
            qh = _rot_half(qh, cos, sin)
            k1 = _rot_half(k1, cos, sin)
        q_s[...] = qh
        k1_s[...] = k1
        v1_s[...] = v1
        k1_ref[0] = k1.astype(k1_ref.dtype)
        v1_ref[0] = v1.astype(v1_ref.dtype)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_s[...] * inv_sqrt
    k = k_ref[0, :, 0].astype(jnp.float32)                       # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, bs)
    slot = ss * block_s + jax.lax.broadcasted_iota(jnp.int32, (G, block_s), 1)
    # pre-write cache: nv slots are valid, minus the one the current
    # token is about to overwrite (rolling windows at pos >= W)
    s = jnp.where((slot < nv_ref[b]) & (slot != ev_ref[b]), s, NEG)

    m_prev = m_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ss == n_s - 1)
    def _():
        # the current token as one more online-softmax step
        qf = q_s[...] * inv_sqrt
        s_cur = jnp.dot(qf, k1_s[...].T,
                        preferred_element_type=jnp.float32)      # (G, 1)
        m_prev = m_ref[:, :1]
        m_fin = jnp.maximum(m_prev, s_cur)
        p = jnp.exp(s_cur - m_fin)
        alpha = jnp.exp(m_prev - m_fin)
        l_fin = alpha * l_ref[:, :1] + p
        acc_fin = acc_ref[...] * alpha + jnp.dot(
            p, v1_s[...], preferred_element_type=jnp.float32)
        attn = acc_fin / jnp.maximum(l_fin, 1e-30)               # (G, dh)
        o_c = _qproject(attn.reshape(1, G * dh), wo_ref[...], so_ref[...],
                        a8)

        @pl.when(h == 0)
        def _():
            o_ref[...] = o_c.astype(o_ref.dtype)

        @pl.when(h > 0)
        def _():
            o_ref[...] += o_c.astype(o_ref.dtype)


def _fused_paged_body(nv_ref, ev_ref, tbl_ref, *rest, **kw):
    """Paged flavor: the block table is consumed only by the BlockSpec
    index maps; the body itself is the contiguous kernel."""
    del tbl_ref
    _fused_body(nv_ref, ev_ref, *rest, **kw)


def flash_decode_fused(x, wq, sq, wk, sk, wv, sv, wo, so, k_cache, v_cache,
                       n_valid, evict, cos, sin, *, block_s: int = DEFAULT_BS,
                       use_rope: bool = True, a8: bool = False,
                       interpret: bool = False):
    """Fused quantized decode-attention over a contiguous slot cache.

    x (B, D) hidden rows; wq (D, nh*dh)/wk, wv (D, nkv*dh) int8 with
    (1, cols) f32 scales; wo (nh*dh, D) int8 + (1, D) scale; k/v_cache
    (B, W, nkv, dh) PRE-write; n_valid (B,) valid slots (= pos), evict
    (B,) slot the current token will overwrite (-1 = none); cos/sin
    (1, dh/2) rope rows for the current position.  Returns
    (o (B, D), k1 (B, nkv, dh), v1 (B, nkv, dh)) — the caller writes
    k1/v1 at slot pos.
    """
    B, D = x.shape
    W, nkv, dh = k_cache.shape[1], k_cache.shape[2], k_cache.shape[3]
    nh = wq.shape[1] // dh
    G = nh // nkv
    block_s = min(block_s, W)
    assert W % block_s == 0, (W, block_s)
    n_s = W // block_s

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, n_s),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, h, s, *pf: (b, 0)),        # x
            pl.BlockSpec((1, dh // 2), lambda b, h, s, *pf: (0, 0)),  # cos
            pl.BlockSpec((1, dh // 2), lambda b, h, s, *pf: (0, 0)),  # sin
            pl.BlockSpec((D, G * dh), lambda b, h, s, *pf: (0, h)),   # wq
            pl.BlockSpec((1, G * dh), lambda b, h, s, *pf: (0, h)),
            pl.BlockSpec((D, dh), lambda b, h, s, *pf: (0, h)),       # wk
            pl.BlockSpec((1, dh), lambda b, h, s, *pf: (0, h)),
            pl.BlockSpec((D, dh), lambda b, h, s, *pf: (0, h)),       # wv
            pl.BlockSpec((1, dh), lambda b, h, s, *pf: (0, h)),
            pl.BlockSpec((G * dh, D), lambda b, h, s, *pf: (h, 0)),   # wo
            pl.BlockSpec((1, D), lambda b, h, s, *pf: (0, 0)),
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, *pf: (b, s, h, 0)),          # k
            pl.BlockSpec((1, block_s, 1, dh),
                         lambda b, h, s, *pf: (b, s, h, 0)),          # v
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda b, h, s, *pf: (b, 0)),        # o
            pl.BlockSpec((1, 1, dh), lambda b, h, s, *pf: (b, h, 0)),  # k1
            pl.BlockSpec((1, 1, dh), lambda b, h, s, *pf: (b, h, 0)),  # v1
        ],
        scratch_shapes=[pltpu.VMEM((G, dh), jnp.float32),   # q
                        pltpu.VMEM((1, dh), jnp.float32),   # k1
                        pltpu.VMEM((1, dh), jnp.float32),   # v1
                        pltpu.VMEM((G, 128), jnp.float32),  # m
                        pltpu.VMEM((G, 128), jnp.float32),  # l
                        pltpu.VMEM((G, dh), jnp.float32)],  # acc
    )
    out_shapes = [jax.ShapeDtypeStruct((B, D), x.dtype),
                  jax.ShapeDtypeStruct((B, nkv, dh), x.dtype),
                  jax.ShapeDtypeStruct((B, nkv, dh), x.dtype)]
    o, k1, v1 = pl.pallas_call(
        functools.partial(_fused_body, n_s=n_s, block_s=block_s,
                          use_rope=use_rope, a8=a8),
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32), jnp.asarray(evict, jnp.int32),
      x, cos, sin, wq, sq, wk, sk, wv, sv, wo, so, k_cache, v_cache)
    return o, k1, v1


def flash_decode_fused_paged(x, wq, sq, wk, sk, wv, sv, wo, so, k_pages,
                             v_pages, table, n_valid, evict, cos, sin, *,
                             use_rope: bool = True, a8: bool = False,
                             interpret: bool = False):
    """Paged-table flavor of :func:`flash_decode_fused`: K/V live in the
    node-wide page arena (P, block_tokens, nkv, dh) and grid axis 2
    walks LOGICAL blocks through the scalar-prefetched table, exactly as
    ``flash_decode_paged``.  Returns (o, k1, v1); the caller writes
    k1/v1 into page ``table[b, pos // bt]`` offset ``pos % bt``.
    """
    B, D = x.shape
    bt, nkv, dh = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    n_b = table.shape[1]
    nh = wq.shape[1] // dh
    G = nh // nkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nkv, n_b),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, h, j, *pf: (b, 0)),        # x
            pl.BlockSpec((1, dh // 2), lambda b, h, j, *pf: (0, 0)),  # cos
            pl.BlockSpec((1, dh // 2), lambda b, h, j, *pf: (0, 0)),  # sin
            pl.BlockSpec((D, G * dh), lambda b, h, j, *pf: (0, h)),   # wq
            pl.BlockSpec((1, G * dh), lambda b, h, j, *pf: (0, h)),
            pl.BlockSpec((D, dh), lambda b, h, j, *pf: (0, h)),       # wk
            pl.BlockSpec((1, dh), lambda b, h, j, *pf: (0, h)),
            pl.BlockSpec((D, dh), lambda b, h, j, *pf: (0, h)),       # wv
            pl.BlockSpec((1, dh), lambda b, h, j, *pf: (0, h)),
            pl.BlockSpec((G * dh, D), lambda b, h, j, *pf: (h, 0)),   # wo
            pl.BlockSpec((1, D), lambda b, h, j, *pf: (0, 0)),
            # page indirection: logical block j -> physical page tbl[b, j]
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, ev, tbl: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, ev, tbl: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda b, h, j, *pf: (b, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h, j, *pf: (b, h, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h, j, *pf: (b, h, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((G, dh), jnp.float32),
                        pltpu.VMEM((1, dh), jnp.float32),
                        pltpu.VMEM((1, dh), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out_shapes = [jax.ShapeDtypeStruct((B, D), x.dtype),
                  jax.ShapeDtypeStruct((B, nkv, dh), x.dtype),
                  jax.ShapeDtypeStruct((B, nkv, dh), x.dtype)]
    o, k1, v1 = pl.pallas_call(
        functools.partial(_fused_paged_body, n_s=n_b, block_s=bt,
                          use_rope=use_rope, a8=a8),
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32), jnp.asarray(evict, jnp.int32),
      jnp.asarray(table, jnp.int32), x, cos, sin, wq, sq, wk, sk, wv, sv,
      wo, so, k_pages, v_pages)
    return o, k1, v1


def _paged_decode_kernel(nv_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_b: int, block_t: int):
    """One (batch, kv-head) pair; grid axis 2 walks the LOGICAL blocks of
    the row's block table.  The page indirection happened in the BlockSpec
    index map (``tbl_ref[b, j]``), so k_ref/v_ref already hold the right
    physical page — the body is the contiguous kernel at block_s=block_t.

    q_ref:  (1, 1, G, dh)
    k_ref:  (1, block_t, 1, dh)   physical page, logical block j
    v_ref:  (1, block_t, 1, dh)
    nv_ref: (B,) int32            valid-slot counts (scalar prefetch)
    tbl_ref:(B, n_b) int32        block table (scalar prefetch)
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / (dh ** 0.5))
    k = k_ref[0, :, 0].astype(jnp.float32)                       # (bt, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, bt)
    slot = j * block_t + jax.lax.broadcasted_iota(jnp.int32, (G, block_t), 1)
    s = jnp.where(slot < nv_ref[pl.program_id(0)], s, NEG)

    m_prev = m_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_b - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       table: jax.Array, n_valid: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """GQA decode attention through a block table.

    q: (B, nh, dh); k_pages/v_pages: (P, block_tokens, nkv, dh) — the
    node-wide page arena; table: (B, n_b) int32, logical block j of row b
    lives in physical page ``table[b, j]``; n_valid: scalar or (B,) valid
    LOGICAL slot count.  Returns (B, nh, dh).
    """
    B, nh, dh = q.shape
    P, bt, nkv, _ = k_pages.shape
    n_b = table.shape[1]
    G = nh // nkv
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (B,))
    tbl = jnp.asarray(table, jnp.int32)

    qg = q.reshape(B, nkv, G, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh),
                         lambda b, h, j, nv, tbl: (b, h, 0, 0)),
            # page indirection: logical block j -> physical page tbl[b, j]
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, tbl: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, dh),
                         lambda b, h, j, nv, tbl: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda b, h, j, nv, tbl: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, n_b=n_b, block_t=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nv, tbl, qg, k_pages, v_pages)
    return out.reshape(B, nh, dh)
