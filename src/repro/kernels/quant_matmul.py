"""Pallas TPU quantized-matmul kernels (W8A8 / W8A16 / W4A16).

Three tiers (DESIGN.md §3):

* **W8A8** (``_mm_kernel_w8a8``): activations arrive PRE-quantized to
  int8 with per-row absmax scales (ops.py does the dynamic rowwise
  quantization once per call, over the full K axis); the kernel runs an
  int8 x int8 dot with **int32 accumulation** on the MXU and applies a
  single per-(row, output-channel) rescale ``acc * sx * sw`` at writeout
  on the last K step.  No f32 weight tile is ever materialized — HBM
  *and* MXU both see the low-bit operands.  int32 is overflow-safe:
  |acc| <= 127*127*K < 2^31 for K < ~133k, far beyond any d_model/d_ff
  served here.

* **W8A16 / W4A16** (``_mm_kernel_int8`` / ``_mm_kernel_int4``): the
  high-accuracy fallback — int8/int4 weights stream HBM->VMEM in
  (block_k, block_n) tiles, are dequantized *in VMEM* (vector unit), and
  feed the MXU as f32 tiles, so the HBM side sees alpha x fewer bytes
  while the MXU sees ordinary matmuls.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") so a VMEM scratch
accumulator (f32 for the A16 tiers, int32 for W8A8) carries partial sums
across K steps; the result is rescaled/cast and written once on the last
K step.

int4: weights arrive packed two-rows-per-int8 (quant/ptq.py layout:
row 2i -> low nibble, row 2i+1 -> high nibble), so the weight BlockSpec
tiles (bk/2, bn) and the kernel unpacks to (bk, bn) with an index-free
even/odd reconstruction (``_unpack_int4_tile``) — the packed form is
what lives in HBM/VMEM, which is the point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _unpack_int4_tile(packed: jax.Array) -> jax.Array:
    """(R, C) packed int8 -> (2R, C) int4 values in [-8, 7], index-free.

    Output row r reads packed row r//2 (a sublane repeat — no
    stack+reshape interleave tile in VMEM), then a parity-selected shift
    sign-extends the right nibble: even rows ``(x << 4) >> 4`` (low
    nibble), odd rows ``x >> 4`` (high nibble), both arithmetic on int8.
    Operand values and ordering match the historical stack-based unpack
    exactly, so downstream dots are bitwise-identical.
    """
    rep = jnp.repeat(packed, 2, axis=0)                   # (2R, C)
    row = jax.lax.broadcasted_iota(jnp.int32, rep.shape, 0)
    lshift = jnp.where(row % 2 == 0, 4, 0).astype(jnp.int8)
    return ((rep << lshift) >> 4).astype(jnp.int8)


def _mm_kernel_int8(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    """W8A16: one (bm, bn) output tile, accumulating over K blocks."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_int4(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    """W4A16: as _mm_kernel_int8 but unpacking the nibble-packed tile."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_int4_tile(q_ref[...])                     # (bk, bn) int8
    w = q.astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_w8a8(x_ref, sx_ref, q_ref, s_ref, o_ref, acc_ref, *,
                    n_k: int):
    """W8A8: int8 x int8 -> int32 accumulation, ONE rescale at writeout.

    x_ref holds pre-quantized int8 activations, sx_ref their per-row f32
    scales (full-K absmax/127, so the scale is K-block-invariant and the
    rescale factorizes out of the accumulation); s_ref the per-channel
    weight scales.  The MXU consumes the int8 operands directly.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[...].astype(jnp.float32)
                      * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array,
                 bits: int = 8, *, x_scale: jax.Array = None,
                 out_dtype=None, block_m: int = DEFAULT_BM,
                 block_n: int = DEFAULT_BN, block_k: int = DEFAULT_BK,
                 interpret: bool = False) -> jax.Array:
    """x (M,K) @ dequant(q (K,N) or packed (K/2,N), scale (N,)) -> (M,N).

    With ``x_scale`` (M, 1) the W8A8 tier runs: x must already be int8
    (rowwise-quantized by ops.py) and the output is
    ``(x_int32 @ q_int32) * x_scale * scale`` in ``out_dtype``.
    M, K, N must be divisible by the block sizes (ops.py pads).
    """
    M, K = x.shape
    N = scale.shape[0]
    a8 = x_scale is not None
    if bits == 4:
        assert q.shape == (K // 2, N), (q.shape, K, N)
        assert block_k % 2 == 0
    else:
        assert q.shape == (K, N), (q.shape, K, N)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k
    out_dtype = out_dtype if out_dtype is not None else x.dtype

    if a8:
        assert bits == 8 and x.dtype == jnp.int8, (bits, x.dtype)
        assert x_scale.shape == (M, 1), x_scale.shape
        return pl.pallas_call(
            functools.partial(_mm_kernel_w8a8, n_k=n_k),
            grid=(M // block_m, N // block_n, n_k),
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
                pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
                pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
                pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(x, x_scale.astype(jnp.float32), q,
          scale.reshape(1, N).astype(jnp.float32))

    kern = _mm_kernel_int4 if bits == 4 else _mm_kernel_int8
    wk = block_k // 2 if bits == 4 else block_k
    return pl.pallas_call(
        functools.partial(kern, n_k=n_k),
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((wk, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, q, scale.reshape(1, N).astype(jnp.float32))
