"""Pallas TPU dequant-matmul kernel (W8A16 / W4A16).

The paper's quantization saves HBM capacity and bandwidth; the compute
cost is re-expanding the low-bit weights.  The TPU-native design
(DESIGN.md §3): int8/int4 weights stream HBM->VMEM in (block_k, block_n)
tiles, are dequantized *in VMEM* (vector unit), and feed the MXU as f32
tiles — so the HBM side sees alpha x fewer bytes while the MXU sees
ordinary matmuls.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") so a VMEM scratch
accumulator carries partial sums across K steps; the f32 result is cast
and written once on the last K step.

int4: weights arrive packed two-rows-per-int8 (quant/ptq.py layout:
row 2i -> low nibble, row 2i+1 -> high nibble), so the weight BlockSpec
tiles (bk/2, bn) and the kernel unpacks to (bk, bn) with vector ops —
the packed form is what lives in HBM/VMEM, which is the point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _mm_kernel_int8(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile, accumulating over K blocks."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_int4(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = q_ref[...]                                   # (bk/2, bn) int8
    lo = (packed & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    hi = jnp.where(hi > 7, hi - 16, hi)
    bk2, bn = packed.shape
    q = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)  # rows interleaved
    w = q.astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array,
                 bits: int = 8, *, block_m: int = DEFAULT_BM,
                 block_n: int = DEFAULT_BN, block_k: int = DEFAULT_BK,
                 interpret: bool = False) -> jax.Array:
    """x (M,K) @ dequant(q (K,N) or packed (K/2,N), scale (N,)) -> (M,N).

    M, K, N must be divisible by the block sizes (ops.py pads).
    """
    M, K = x.shape
    N = scale.shape[0]
    if bits == 4:
        assert q.shape == (K // 2, N), (q.shape, K, N)
        assert block_k % 2 == 0
    else:
        assert q.shape == (K, N), (q.shape, K, N)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k

    kern = _mm_kernel_int4 if bits == 4 else _mm_kernel_int8
    wk = block_k // 2 if bits == 4 else block_k
    return pl.pallas_call(
        functools.partial(kern, n_k=n_k),
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((wk, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, q, scale.reshape(1, N).astype(jnp.float32))
