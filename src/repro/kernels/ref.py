"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x: jax.Array, q: jax.Array, scale: jax.Array,
                     bits: int = 8) -> jax.Array:
    """x (M,K) @ dequant(q, scale) -> (M,N) in x.dtype.

    q: int8 (K,N) for bits=8, packed (K/2,N) for bits=4 (see quant/ptq.py);
    scale: (N,) float32 per-output-channel.
    """
    if bits == 4:
        from repro.quant.ptq import unpack_int4
        q = unpack_int4(q)
    w = q.astype(jnp.float32) * scale.astype(jnp.float32)
    out = x.astype(jnp.float32) @ w
    return out.astype(x.dtype)


def quant_matmul_a8_ref(x: jax.Array, q: jax.Array,
                        scale: jax.Array) -> jax.Array:
    """W8A8 oracle: dynamic rowwise activation quantization, exact int32
    dot, one per-(row, channel) rescale at writeout.

    The int32 contraction is EXACT integer math (no rounding), so the
    Pallas kernel's blocked int32 accumulation must match it bit for bit
    before the final f32 rescale — tests exploit that.
    """
    from repro.quant.ptq import quantize_rowwise
    xq, sx = quantize_rowwise(x)
    acc = jax.lax.dot_general(xq.astype(jnp.int32), q.astype(jnp.int32),
                              (((1,), (0,)), ((), ())))
    out = acc.astype(jnp.float32) * sx \
        * scale.reshape(1, -1).astype(jnp.float32)
    return out.astype(x.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     n_valid: jax.Array) -> jax.Array:
    """GQA decode attention oracle.

    q: (B, nh, dh) current-step queries (rope already applied);
    k, v: (B, W, nkv, dh) slot caches; n_valid: scalar or (B,) count of
    valid cache slots.  Returns (B, nh, dh) in q.dtype.
    """
    B, nh, dh = q.shape
    W, nkv = k.shape[1], k.shape[2]
    G = nh // nkv
    qf = q.reshape(B, nkv, G, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * scale    # (B,nkv,G,W)
    nv = jnp.broadcast_to(jnp.asarray(n_valid), (B,))
    mask = jnp.arange(W)[None, :] < nv[:, None]                # (B,W)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf)
    return out.reshape(B, nh, dh).astype(q.dtype)
