"""Jit'd public wrappers for the Pallas kernels.

These handle shape padding (kernels need block-divisible dims), dtype
plumbing, and the interpret-mode switch (CPU validation; TPU is the
target).  Model code calls only these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as _fd
from repro.kernels import quant_matmul as _qm
from repro.quant.ptq import QTensor

# CPU containers run kernels in interpret mode; on TPU this is False.
INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n",
                                             "block_k"))
def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array,
                 bits: int = 8, block_m: int = 128, block_n: int = 128,
                 block_k: int = 256) -> jax.Array:
    """x (..., K) @ dequant(q, scale) -> (..., N).  Pads to block multiples."""
    *lead, K = x.shape
    N = scale.shape[0]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)

    bm = min(block_m, max(8, 1 << (M - 1).bit_length()))
    x2 = _pad_to(x2, 0, bm)
    x2 = _pad_to(x2, 1, block_k)
    Kp = x2.shape[1]
    if bits == 4:
        qp = _pad_to(q, 0, block_k // 2)
        assert qp.shape[0] == Kp // 2, (qp.shape, Kp)
    else:
        qp = _pad_to(q, 0, block_k)
    qp = _pad_to(qp, 1, block_n)
    sp = _pad_to(scale.reshape(-1), 0, block_n)

    out = _qm.quant_matmul(x2, qp, sp, bits, block_m=bm, block_n=block_n,
                           block_k=block_k, interpret=INTERPRET)
    return out[:M, :N].reshape(*lead, N)


def qmatmul(x: jax.Array, w) -> jax.Array:
    """Dispatch on weight type: QTensor -> Pallas kernel; array -> XLA."""
    if isinstance(w, QTensor):
        return quant_matmul(x, w.q, w.scale, w.bits)
    return x @ w


@functools.partial(jax.jit, static_argnames=("block_s",))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 n_valid, block_s: int = 512) -> jax.Array:
    """GQA decode attention: q (B, nh, dh) against k/v (B, W, nkv, dh).

    Pads W up to a block multiple (padded slots are masked by n_valid),
    dh up to 128 lanes.
    """
    B, nh, dh = q.shape
    W = k.shape[1]
    bs = min(block_s, max(128, 1 << (W - 1).bit_length()))
    k = _pad_to(k, 1, bs)
    v = _pad_to(v, 1, bs)
    if dh % 128:
        # kernel scales by 1/sqrt(padded dh); compensate so the net
        # softmax scale stays 1/sqrt(true dh)
        dh_p = dh + (128 - dh % 128)
        q = q * jnp.asarray((dh_p / dh) ** 0.5, q.dtype)
        q = _pad_to(q, 2, 128)
        k = _pad_to(k, 3, 128)
        v = _pad_to(v, 3, 128)
    out = _fd.flash_decode(q, k, v, jnp.asarray(n_valid, jnp.int32),
                           block_s=bs, interpret=INTERPRET)
    return out[..., :dh]


@jax.jit
def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       table: jax.Array, n_valid) -> jax.Array:
    """GQA decode attention through a block table (DESIGN.md §2.3).

    q (B, nh, dh) against a page arena k/v (P, block_tokens, nkv, dh);
    table (B, n_b) int32 maps logical block j of row b to its physical
    page.  Pads dh up to 128 lanes (with softmax-scale compensation, as
    in ``flash_decode``); pages are fixed-size so no W padding is needed.
    """
    dh = q.shape[2]
    if dh % 128:
        dh_p = dh + (128 - dh % 128)
        q = q * jnp.asarray((dh_p / dh) ** 0.5, q.dtype)
        q = _pad_to(q, 2, 128)
        k_pages = _pad_to(k_pages, 3, 128)
        v_pages = _pad_to(v_pages, 3, 128)
    out = _fd.flash_decode_paged(q, k_pages, v_pages,
                                 jnp.asarray(table, jnp.int32),
                                 jnp.asarray(n_valid, jnp.int32),
                                 interpret=INTERPRET)
    return out[..., :dh]
