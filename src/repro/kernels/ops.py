"""Jit'd public wrappers for the Pallas kernels.

These handle shape padding (kernels need block-divisible dims), dtype
plumbing, and the interpret-mode switch (CPU validation; TPU is the
target).  Model code calls only these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as _fd
from repro.kernels import quant_matmul as _qm
from repro.quant.ptq import QTensor

# CPU containers run kernels in interpret mode; on TPU this is False.
INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bits", "act_bits", "block_m",
                                             "block_n", "block_k"))
def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array,
                 bits: int = 8, act_bits: int = 16, block_m: int = 128,
                 block_n: int = 128, block_k: int = 256) -> jax.Array:
    """x (..., K) @ dequant(q, scale) -> (..., N).  Pads to block multiples.

    ``act_bits=8`` (with ``bits=8``) runs the W8A8 tier: x is dynamically
    quantized per row (absmax/127 over the full K axis) HERE, outside the
    grid, so the kernel sees int8 operands and one (M, 1) scale — the
    int8 x int8 dot accumulates in int32 and rescales once at writeout.
    """
    *lead, K = x.shape
    N = scale.shape[0]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    a8 = act_bits == 8 and bits == 8

    # int8 operands need a (32, 128) min tile on real TPUs (interpret
    # mode doesn't care); f32 needs (8, 128)
    bm = min(block_m, max(32 if a8 else 8, 1 << (M - 1).bit_length()))
    if a8:
        from repro.quant.ptq import quantize_rowwise
        xq, sx = quantize_rowwise(x2)
        x2 = _pad_to(xq, 0, bm)
        sxp = _pad_to(sx, 0, bm)
    else:
        x2 = _pad_to(x2, 0, bm)
        sxp = None
    x2 = _pad_to(x2, 1, block_k)
    Kp = x2.shape[1]
    if bits == 4:
        qp = _pad_to(q, 0, block_k // 2)
        assert qp.shape[0] == Kp // 2, (qp.shape, Kp)
    else:
        qp = _pad_to(q, 0, block_k)
    qp = _pad_to(qp, 1, block_n)
    sp = _pad_to(scale.reshape(-1), 0, block_n)

    out = _qm.quant_matmul(x2, qp, sp, bits, x_scale=sxp,
                           out_dtype=x.dtype, block_m=bm, block_n=block_n,
                           block_k=block_k, interpret=INTERPRET)
    return out[:M, :N].reshape(*lead, N)


def qmatmul(x: jax.Array, w) -> jax.Array:
    """Dispatch on weight type: QTensor -> Pallas kernel; array -> XLA.
    QTensor leaves tagged ``act_bits=8`` route to the W8A8 tier."""
    if isinstance(w, QTensor):
        return quant_matmul(x, w.q, w.scale, w.bits, act_bits=w.act_bits)
    return x @ w


def _rope_rows(pos, dh: int, theta: float):
    """cos/sin (1, dh/2) rows for the current decode position (the same
    angle convention as models/common.apply_rope)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = jnp.asarray(pos, jnp.float32) * freqs
    return jnp.cos(ang).reshape(1, -1), jnp.sin(ang).reshape(1, -1)


def fusable_decode(p, cfg) -> bool:
    """True when a layer's attention params can take the fused quantized
    decode kernel: all four projections are int8 QTensors (W8A16 or W8A8
    — int4 stays on the unfused tier), no qk-norm (applied between
    projection and rope, which the fused grid doesn't model), and the
    head dim is lane-aligned unless we're interpreting."""
    ws = [p.get("wq"), p.get("wk"), p.get("wv"), p.get("wo")]
    return (all(isinstance(w, QTensor) and w.bits == 8 for w in ws)
            and not cfg.qk_norm
            and (cfg.d_head % 128 == 0 or INTERPRET))


def decode_kernel_tier(p, cfg) -> str:
    """Which decode-attention tier a kernel-routed step takes for layer
    params ``p`` under ``cfg`` (mirrors the dispatch in
    ``models/common.decode_attention[_paged]``): ``"kv8"`` — int8 KV
    cache, kernels bypassed (the dequant-read path has no kernel tier);
    ``"fused"`` — int8 projections through ``flash_decode_fused``;
    ``"flash"`` — fp weights through ``flash_decode``.  Introspection
    for engines/tests asserting what ``use_kernel=True`` actually
    routes to — dequantized trees (interpret-mode serving) report
    ``"flash"`` because ``fusable_decode`` is False for them."""
    if cfg.kv_bits == 8:
        return "kv8"
    return "fused" if fusable_decode(p, cfg) else "flash"


@functools.partial(jax.jit, static_argnames=("rope_theta", "use_rope",
                                             "block_s"))
def flash_decode_fused(x: jax.Array, wq, wk, wv, wo, cache_k: jax.Array,
                       cache_v: jax.Array, pos, rope_theta: float = 1e4,
                       use_rope: bool = True, block_s: int = 512):
    """Fused quantized decode attention (contiguous cache).

    x (B, D) pre-norm hidden rows; wq/wk/wv/wo int8 QTensors; caches
    (B, W, nkv, dh) PRE-write.  The QKV/output projections run on int8
    weight tiles inside the decode grid (W8A8 when the tensors carry
    ``act_bits=8``); the kernel attends over the pre-write cache plus the
    freshly-projected current token, so its output equals project ->
    rope -> cache_write -> flash_decode -> wo on the post-write cache.
    Returns (o (B, D), k1 (B, nkv, dh), v1 (B, nkv, dh)); the CALLER
    writes k1/v1 at slot pos % W.
    """
    B, W, nkv, dh = cache_k.shape[0], cache_k.shape[1], cache_k.shape[2], \
        cache_k.shape[3]
    assert wq.bits == 8 and wo.bits == 8, (wq.bits, wo.bits)
    assert dh % 128 == 0 or INTERPRET, dh
    a8 = wq.act_bits == 8
    bs = min(block_s, max(128, 1 << (W - 1).bit_length()))
    ck = _pad_to(cache_k, 1, bs)
    cv = _pad_to(cache_v, 1, bs)
    posi = jnp.asarray(pos, jnp.int32)
    nv = jnp.broadcast_to(jnp.minimum(posi, W), (B,))
    # slot the current token is about to overwrite: invalid in the
    # pre-write read once the window has wrapped (pos >= W)
    ev = jnp.broadcast_to(jnp.where(posi >= W, posi % W, -1), (B,))
    cos, sin = _rope_rows(posi, dh, rope_theta)
    return _fd.flash_decode_fused(
        x, wq.q, wq.scale.reshape(1, -1), wk.q, wk.scale.reshape(1, -1),
        wv.q, wv.scale.reshape(1, -1), wo.q, wo.scale.reshape(1, -1),
        ck, cv, nv, ev, cos, sin, block_s=bs, use_rope=use_rope, a8=a8,
        interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("rope_theta", "use_rope"))
def flash_decode_fused_paged(x: jax.Array, wq, wk, wv, wo,
                             k_pages: jax.Array, v_pages: jax.Array,
                             table: jax.Array, pos,
                             rope_theta: float = 1e4,
                             use_rope: bool = True):
    """Paged-table flavor of :func:`flash_decode_fused`: k/v_pages
    (P, block_tokens, nkv, dh) arena slices (tail-sliced to the model's
    geometry by the caller), table (B, n_b) int32.  Returns (o, k1, v1);
    the caller writes k1/v1 into page ``table[b, pos // bt]``."""
    B = x.shape[0]
    bt, dh = k_pages.shape[1], k_pages.shape[3]
    W = table.shape[1] * bt
    assert wq.bits == 8 and wo.bits == 8, (wq.bits, wo.bits)
    assert dh % 128 == 0 or INTERPRET, dh
    a8 = wq.act_bits == 8
    posi = jnp.asarray(pos, jnp.int32)
    nv = jnp.broadcast_to(jnp.minimum(posi, W), (B,))
    ev = jnp.broadcast_to(jnp.where(posi >= W, posi % W, -1), (B,))
    cos, sin = _rope_rows(posi, dh, rope_theta)
    return _fd.flash_decode_fused_paged(
        x, wq.q, wq.scale.reshape(1, -1), wk.q, wk.scale.reshape(1, -1),
        wv.q, wv.scale.reshape(1, -1), wo.q, wo.scale.reshape(1, -1),
        k_pages, v_pages, jnp.asarray(table, jnp.int32), nv, ev, cos, sin,
        use_rope=use_rope, a8=a8, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_s",))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 n_valid, block_s: int = 512) -> jax.Array:
    """GQA decode attention: q (B, nh, dh) against k/v (B, W, nkv, dh).

    Pads W up to a block multiple (padded slots are masked by n_valid),
    dh up to 128 lanes.
    """
    B, nh, dh = q.shape
    W = k.shape[1]
    bs = min(block_s, max(128, 1 << (W - 1).bit_length()))
    k = _pad_to(k, 1, bs)
    v = _pad_to(v, 1, bs)
    if dh % 128:
        # kernel scales by 1/sqrt(padded dh); compensate so the net
        # softmax scale stays 1/sqrt(true dh)
        dh_p = dh + (128 - dh % 128)
        q = q * jnp.asarray((dh_p / dh) ** 0.5, q.dtype)
        q = _pad_to(q, 2, 128)
        k = _pad_to(k, 3, 128)
        v = _pad_to(v, 3, 128)
    out = _fd.flash_decode(q, k, v, jnp.asarray(n_valid, jnp.int32),
                           block_s=bs, interpret=INTERPRET)
    return out[..., :dh]


@jax.jit
def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       table: jax.Array, n_valid) -> jax.Array:
    """GQA decode attention through a block table (DESIGN.md §2.3).

    q (B, nh, dh) against a page arena k/v (P, block_tokens, nkv, dh);
    table (B, n_b) int32 maps logical block j of row b to its physical
    page.  Pads dh up to 128 lanes (with softmax-scale compensation, as
    in ``flash_decode``); pages are fixed-size so no W padding is needed.
    """
    dh = q.shape[2]
    if dh % 128:
        dh_p = dh + (128 - dh % 128)
        q = q * jnp.asarray((dh_p / dh) ** 0.5, q.dtype)
        q = _pad_to(q, 2, 128)
        k_pages = _pad_to(k_pages, 3, 128)
        v_pages = _pad_to(v_pages, 3, 128)
    out = _fd.flash_decode_paged(q, k_pages, v_pages,
                                 jnp.asarray(table, jnp.int32),
                                 jnp.asarray(n_valid, jnp.int32),
                                 interpret=INTERPRET)
    return out[..., :dh]
