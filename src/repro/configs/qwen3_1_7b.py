"""Qwen3-1.7B — qk-norm, GQA [hf:Qwen/Qwen3-8B family card]."""
from repro.config import ModelConfig, register_arch

QWEN3_1_7B = register_arch(ModelConfig(
    arch_id="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    norm="rmsnorm",
    act="silu",
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B (1.7B sibling card)",
))
