"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.config import ModelConfig, XLSTMConfig, register_arch

XLSTM_1_3B = register_arch(ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                 # xLSTM blocks carry their own projections
    vocab=50304,
    norm="layernorm",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0,
                      proj_factor_slstm=4.0 / 3.0, conv_width=4),
    source="arXiv:2405.04517 (xLSTM: Extended Long Short-Term Memory)",
    notes="Recurrent matrix/scalar memory; decode state is O(1) in context "
          "length, so long_500k applies.",
))
