"""OPT-13B — one of the paper's own simulation models (Table I)."""
from repro.config import ModelConfig, register_arch

OPT_13B = register_arch(ModelConfig(
    arch_id="opt-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=4 * 5120,
    vocab=50272,
    norm="layernorm",
    act="relu",             # OPT uses ReLU (matches the paper's f_relu eqs)
    tie_embeddings=True,
    source="paper Table I [2]; hf:facebook/opt-13b",
))
