"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.config import HybridConfig, ModelConfig, SSMConfig, register_arch

ZAMBA2_7B = register_arch(ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    norm="rmsnorm",
    act="silu",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128, conv_width=4),
    hybrid=HybridConfig(attn_every=6, shared_attn=True),
    source="arXiv:2411.15242 (Zamba2)",
    notes="81 Mamba2 layers; one SHARED attention+FFN block applied every "
          "6th layer (weights reused). O(1) SSM decode state => long_500k "
          "applies; the shared-attn KV cache at the attn sites is the only "
          "seq-dependent memory and is windowed to 4096 for long_500k.",
))
