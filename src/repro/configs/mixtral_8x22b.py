"""Mixtral-8x22B — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.config import ModelConfig, MoEConfig, register_arch

MIXTRAL_8X22B = register_arch(ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,              # per expert
    vocab=32768,
    norm="rmsnorm",
    act="silu",
    sliding_window=4096,     # per the assignment (SWA)
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088 (Mixtral of Experts)",
    notes="SWA bounds the decode KV cache to the window => long_500k applies.",
))
