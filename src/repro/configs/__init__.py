"""Per-architecture configuration modules.

One module per assigned architecture (plus the paper's own BLOOM/OPT models).
Each module registers exactly one ``ModelConfig`` with the exact dimensions
cited from its source paper / model card.
"""
