"""BLOOM-3B — one of the paper's own simulation models (Table I)."""
from repro.config import ModelConfig, register_arch

BLOOM_3B = register_arch(ModelConfig(
    arch_id="bloom-3b",
    family="dense",
    n_layers=30,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=4 * 2560,          # "The FFN's dimension is four times the model's"
    vocab=250880,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="paper Table I [2]; hf:bigscience/bloom-3b",
))
