"""Granite-3.0-1B-A400M — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.config import ModelConfig, MoEConfig, register_arch

GRANITE_MOE_1B_A400M = register_arch(ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                # per expert
    vocab=49155,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="vocab 49155 padded to 49408 for model-parallel vocab sharding.",
))
