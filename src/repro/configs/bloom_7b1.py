"""BLOOM-7.1B — one of the paper's own simulation models (Table I)."""
from repro.config import ModelConfig, register_arch

BLOOM_7B1 = register_arch(ModelConfig(
    arch_id="bloom-7b1",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=4 * 4096,
    vocab=250880,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="paper Table I [2]; hf:bigscience/bloom-7b1",
))
