"""InternVL2-26B language backbone (InternLM2-20B-style) [arXiv:2404.16821].

The vision side (InternViT-6B + MLP projector) is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings of shape
``(batch, n_img_tokens, d_model)``; this config describes the transformer
decoder that consumes them.
"""
from repro.config import ModelConfig, VLMConfig, register_arch

INTERNVL2_26B = register_arch(ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    norm="rmsnorm",
    act="silu",
    vlm=VLMConfig(n_img_tokens=256),
    source="arXiv:2404.16821 (InternVL2); LM backbone InternLM2",
    notes="vocab 92553 padded to 92672 (multiple of 256) for 16-way vocab "
          "sharding; logits masked beyond the true vocab.",
))
