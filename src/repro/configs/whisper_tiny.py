"""Whisper-tiny — encoder-decoder with conv frontend stub [arXiv:2212.04356].

The mel-spectrogram + conv1d feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings ``(batch, 1500, d_model)``
consumed by the transformer encoder; this config describes the enc-dec
transformer itself.  n_layers refers to the decoder stack.
"""
from repro.config import EncDecConfig, ModelConfig, register_arch

WHISPER_TINY = register_arch(ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=4, n_audio_frames=1500),
    source="arXiv:2212.04356 (Robust Speech Recognition via Large-Scale "
           "Weak Supervision)",
    notes="decode_32k exercises a 32k self-attn cache mechanically even "
          "though real Whisper caps decoding at 448 positions (fidelity "
          "caveat recorded in DESIGN.md). Full attention => long_500k skipped.",
))
