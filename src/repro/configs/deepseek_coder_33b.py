"""DeepSeek-Coder-33B — llama-arch [arXiv:2401.14196]."""
from repro.config import ModelConfig, register_arch

DEEPSEEK_CODER_33B = register_arch(ModelConfig(
    arch_id="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    norm="rmsnorm",
    act="silu",
    rope_theta=100_000.0,
    source="arXiv:2401.14196 (DeepSeek-Coder)",
))
