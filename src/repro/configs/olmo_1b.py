"""OLMo-1B — non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.config import ModelConfig, register_arch

OLMO_1B = register_arch(ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    norm="nonparam_ln",      # OLMo uses LN without learnable affine params
    act="silu",
    tie_embeddings=True,
    source="arXiv:2402.00838 (OLMo: Accelerating the Science of LMs)",
))
