"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.config import ModelConfig, register_arch

MISTRAL_LARGE_123B = register_arch(ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    notes="Pure full attention => long_500k skipped (DESIGN.md §4); the "
          "beyond-paper SWA variant is reported separately in §Perf.",
))
