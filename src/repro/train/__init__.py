from repro.train.optimizer import AdamWState, adamw_init, adamw_update
from repro.train.data import SyntheticLM
from repro.train.trainer import Trainer, TrainState

__all__ = ["AdamWState", "adamw_init", "adamw_update", "SyntheticLM",
           "Trainer", "TrainState"]
