"""Flat-npz checkpointing for arbitrary param/optimizer pytrees."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_np(leaf) -> np.ndarray:
    arr = jnp.asarray(leaf)
    if arr.dtype == jnp.bfloat16:       # npz has no bf16: store f32
        arr = arr.astype(jnp.float32)
    return np.asarray(arr)


def save(path: str, tree: Any) -> None:
    leaves, _ = _flatten(tree)
    arrays = {f"leaf_{i}": _to_np(l) for i, l in enumerate(leaves)}
    tmp = path + ".tmp"
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    leaves, treedef = _flatten(like)
    with np.load(path) as data:
        loaded = [jnp.asarray(data[f"leaf_{i}"], leaves[i].dtype)
                  for i in range(len(leaves))]
    for got, want in zip(loaded, leaves):
        assert got.shape == want.shape, (got.shape, want.shape)
    return jax.tree_util.tree_unflatten(treedef, loaded)
