"""Training loop: jit'd step with optional remat, metrics, checkpoints.

Single-process driver used by examples/ and smoke tests; the distributed
path goes through launch/train.py (same step function under pjit).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.api import Model, build_model
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_update)


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    opt: AdamWState


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    remat: bool = False) -> Callable:
    loss_fn = model.loss_fn
    if remat:
        loss_fn = jax.checkpoint(loss_fn)

    def step(state: TrainState, batch) -> tuple:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return TrainState(new_params, new_opt), metrics

    return step


@dataclass
class Trainer:
    cfg: ModelConfig
    batch: int = 8
    seq: int = 128
    opt_cfg: AdamWConfig = field(default_factory=AdamWConfig)
    remat: bool = False
    seed: int = 0

    def __post_init__(self):
        self.model = build_model(self.cfg)
        self.data = SyntheticLM(self.cfg, self.batch, self.seq,
                                seed=self.seed)
        self._step = jax.jit(make_train_step(self.model, self.opt_cfg,
                                             self.remat))

    def init_state(self) -> TrainState:
        params = self.model.init(jax.random.key(self.seed))
        return TrainState(params, adamw_init(params))

    def run(self, steps: int, state: Optional[TrainState] = None,
            log_every: int = 10, checkpoint_path: Optional[str] = None,
            log: Callable[[str], None] = print) -> tuple:
        state = state or self.init_state()
        history: List[Dict[str, float]] = []
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.next_batch().items()}
            state, metrics = self._step(state, batch)
            if i % log_every == 0 or i == steps - 1:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = i
                history.append(row)
                log(f"step {i:5d}  loss={row['loss']:.4f}  "
                    f"grad_norm={row['grad_norm']:.3f}  lr={row['lr']:.2e}")
        if checkpoint_path:
            ckpt.save(checkpoint_path, (state.params, state.opt))
        return state, history
