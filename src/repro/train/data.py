"""Synthetic LM data pipeline.

Deterministic, seekable token streams (Markov-ish bigram mixture so the
loss actually decreases during the example runs), with the modality-stub
inputs for VLM/audio families.  The pipeline is an iterator of
fixed-shape numpy batches — the launcher shards them across the mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig


@dataclass
class SyntheticLM:
    """Infinite synthetic corpus with learnable bigram structure."""
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    n_states: int = 64          # low-rank bigram structure

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.cfg.vocab
        k = min(self.n_states, V)
        # each state prefers a small set of next tokens
        self._emit = rng.integers(0, V, size=(k, 8))
        self._trans = rng.integers(0, k, size=(k, 8))
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        B, S = self.batch, self.seq
        toks = np.zeros((B, S + 1), np.int32)
        state = rng.integers(0, self._emit.shape[0], size=B)
        for t in range(S + 1):
            choice = rng.integers(0, 8, size=B)
            toks[:, t] = self._emit[state, choice]
            state = self._trans[state, choice]
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        cfg = self.cfg
        if cfg.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (B, cfg.vlm.n_img_tokens, cfg.d_model)).astype(np.float32)
        if cfg.family == "audio":
            out["audio_embeds"] = rng.standard_normal(
                (B, cfg.encdec.n_audio_frames, cfg.d_model)
            ).astype(np.float32)
        return out
