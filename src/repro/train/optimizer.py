"""AdamW in pure JAX (pytree-generic, sharding-transparent).

Moments are stored in f32 regardless of param dtype (bf16-safe); weight
decay is decoupled (AdamW).  The state is a pytree of the same structure
as the params, so pjit shards it exactly like the weights (or ZeRO-style
over ``data`` — see launch/train.py's optimizer_sharding option).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    t = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:     # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_mu, new_nu), metrics
