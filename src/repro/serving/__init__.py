from repro.serving.engine import ServingEngine, GenerationResult

__all__ = ["ServingEngine", "GenerationResult"]
