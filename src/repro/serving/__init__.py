"""Serving: the ServingEngine data plane + the shared EpochRuntime.

``ServingEngine`` / ``GenerationResult`` are lazily re-exported so that
importing the (JAX-free) scheduling runtime does not pull in jax.
"""
from repro.serving.runtime import (AnalyticContinuousExecutor,  # noqa: F401
                                   AnalyticExecutor, ContinuousExecutor,
                                   ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime, Executor)

__all__ = ["ServingEngine", "GenerationResult", "DecodeState",
           "EpochRuntime", "ContinuousRuntime", "Executor",
           "AnalyticExecutor", "EngineExecutor", "ContinuousExecutor",
           "AnalyticContinuousExecutor", "EngineContinuousExecutor"]


def __getattr__(name):
    if name in ("ServingEngine", "GenerationResult", "DecodeState"):
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
