"""End-to-end serving: DFTSP control plane driving the JAX data plane.

``serve_epochs`` runs the paper's epoch protocol where each scheduled
batch is *actually executed* on a (reduced) JAX model by the
ServingEngine — the bridge between the analytic evaluation (core/epoch.py)
and the runtime.  Used by examples/ and integration tests; the paper's
figures come from the analytic ``core.epoch.simulate`` (long horizons).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core import problem
from repro.core.environment import EdgeEnv
from repro.core.epoch import _still_viable
from repro.core.request import Request, RequestGenerator
from repro.core.schedulers import get_scheduler
from repro.serving.engine import ServingEngine


@dataclass
class ServeTrace:
    epochs: int = 0
    served: int = 0
    generated_tokens: int = 0
    batches: List[int] = field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.served / max(self.epochs, 1)


def serve_epochs(env: EdgeEnv, engine: ServingEngine, scheduler: str,
                 rate: float, n_epochs: int = 3, seed: int = 0,
                 rng: Optional[np.random.Generator] = None) -> ServeTrace:
    """Run ``n_epochs`` of schedule -> execute on the real model."""
    sched = get_scheduler(scheduler)
    gen = RequestGenerator(rate=rate, seed=seed)
    rng = rng or np.random.default_rng(seed)
    trace = ServeTrace()
    queue: List[Request] = []

    for e in range(n_epochs):
        t0 = e * env.T_E
        queue.extend(gen.within(t0 - env.T_E, t0) if e else [])
        for r in queue:
            r.t_w = t0 - r.arrival
        queue = [r for r in queue if _still_viable(env, r, t0)]

        sel, _ = sched(env, queue)
        sel = sel[:engine.batch_capacity]
        if sel:
            prompts = [rng.integers(1, engine.cfg.vocab,
                                    size=min(r.s, engine.s_max)).tolist()
                       for r in sel]
            caps = [min(r.n, engine.n_max) for r in sel]
            result = engine.generate(prompts, caps)
            trace.served += result.batch
            trace.generated_tokens += int(result.lengths.sum())
            trace.batches.append(result.batch)
        else:
            trace.batches.append(0)
        chosen = {r.rid for r in sel}
        queue = [r for r in queue if r.rid not in chosen]
        trace.epochs += 1
    return trace
