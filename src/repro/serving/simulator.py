"""End-to-end serving — deprecation shim over the unified runtime.

``serve_epochs`` pairs a ``SchedulerPolicy`` with the ``EngineExecutor``
so every scheduled batch actually executes on the JAX model.  The loop
itself (arrivals, aging, viability drops, selection, removal) lives in
``repro.serving.runtime.EpochRuntime`` — the same loop the analytic
``core.epoch.simulate`` shim drives.

``ServeTrace`` is a deprecated alias of the unified ``EpochMetrics``:
``throughput`` is requests/second (it used to divide by epoch *count*),
and batches exceeding the engine's capacity are clamped with a
feasibility re-check and counted in ``metrics.truncated`` instead of
being silently cut.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.environment import EdgeEnv
from repro.core.metrics import EpochMetrics
from repro.core.policy import SchedulerPolicy
from repro.serving.engine import ServingEngine
from repro.serving.runtime import EngineExecutor, EpochRuntime

# Deprecated alias (pre-redesign name).
ServeTrace = EpochMetrics


def serve_epochs(env: EdgeEnv, engine: ServingEngine,
                 scheduler: Union[str, SchedulerPolicy],
                 rate: float, n_epochs: int = 3, seed: int = 0,
                 rng: Optional[np.random.Generator] = None) -> EpochMetrics:
    """Deprecated shim: ``n_epochs`` of schedule -> execute on the real
    model.  Delegates to ``EpochRuntime`` + ``EngineExecutor``."""
    executor = EngineExecutor(engine, rng=rng, seed=seed)
    runtime = EpochRuntime(env, scheduler, executor)
    return runtime.run(rate=rate, n_epochs=n_epochs, seed=seed,
                       warmup_epochs=0)
