"""Batched-inference engine: executes scheduled batches on the real JAX model.

This is the data plane behind the paper's scheduler (the control plane).
A scheduled batch of prompts is padded to the epoch's s' (exactly the
paper's 'extend all prompts to the maximum length' assumption), prefilled
in one pass, then decoded by a single **device-resident**
``jax.lax.while_loop``: greedy sampling, EOS detection and per-request
output caps are all ``jnp`` ops inside one compiled program, which exits
early once every row is done.  The host never sees a token until the
whole batch finishes — per ``generate`` call there is exactly ONE
host→device transfer (the padded prompts + caps, a single
``jax.device_put``) and ONE device→host transfer (the token buffer +
lengths, a single ``jax.device_get``).  The KV cache produced by prefill
is donated into the decode-loop executable (``donate_argnums``, on
backends that support donation) so the loop carries it in place instead
of copying it at entry.  The historical token-by-token Python loop — one
blocking ``argmax`` transfer per token — survives only as
``generate_reference``, the interpret-style oracle the equivalence tests
compare against.

Static shapes: (batch_capacity, s') for prefill and a KV cache capacity of
s' + n_max — one compiled executable serves every epoch (TPU-friendly, and
why the paper's padded cost model maps 1:1 onto this engine).

The fused loop also exists in RESUMABLE form for continuous batching:
``start_chunked`` prefills a cohort into a device-resident ``DecodeState``,
``generate_chunked(state, k)`` advances it by at most k tokens per call
(one jitted while-loop segment, no host transfer), and ``refill_chunked``
prefills new prompts into slots freed by finished rows of the LIVE cohort
— splicing their cache rows in without touching still-decoding rows.
Driven to completion, chunked decode is bit-identical to ``generate`` for
every chunk size (the equivalence suite in
tests/test_continuous_engine.py).

Weights can be served quantized: ``quant_bits`` picks the DEFAULT
precision, and a per-call ``generate(..., quant_bits=...)`` override lets
the scheduler serve each epoch at the method it decided.  Each requested
bit-width is quantized once from the full-precision weights and kept in a
small multi-precision cache (``params_for``), so swapping precision per
epoch costs a dict lookup.  A precision is an int (weight bits) or a
``(weight_bits, act_bits)`` pair — W8A8 routes the dense matmuls through
the int8-accumulation kernel tier on TPU.  On interpret backends every
family dequantizes at load (see ``params_for`` / DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, get_arch
from repro.models.api import Model, build_model
from repro.quant.ptq import dequantize_tree, quantize_tree
from repro.serving.kv_arena import (TRASH_PAGE, ZERO_PAGE, BlockTable,
                                    KVArena)

# Interpret backends (no TPU) dequantize quantized trees at load and drop
# activation-precision tags — see ServingEngine.params_for.
_INTERPRET = jax.default_backend() != "tpu"


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_max) generated ids (post-prompt)
    lengths: np.ndarray         # (B,) emitted length per request
    batch: int


@dataclass
class DecodeState:
    """Device-resident, re-entrant decode state of one batch cohort.

    Produced by ``start_chunked`` and advanced by ``generate_chunked``;
    everything except ``bits``/``caps_host`` lives on the device, so
    re-entering costs no transfer.  A state passed to ``generate_chunked``
    or ``refill_chunked`` is CONSUMED (its buffers may be donated into the
    compiled segment) — always continue from the returned state.

    ``t`` is the cohort's global decode step: the shared KV-cache write
    position is ``s_max + t``, bounded by ``n_max`` because every row's
    cap (including refills, clamped to the remaining headroom) fits inside
    the cache capacity ``s_max + n_max``.  Rows track their own emission
    via ``lengths``, so rows admitted mid-cohort emit into their row of
    ``out`` from 0 regardless of ``t``.
    """
    cache: Any                  # KV / recurrent cache, full batch capacity
    cur: jax.Array              # (B,) next token to emit per row
    out: jax.Array              # (B, n_max) emitted tokens per row
    lengths: jax.Array          # (B,) emitted count per row
    done: jax.Array             # (B,) bool, EOS seen
    caps: jax.Array             # (B,) per-row output cap (0 = empty slot)
    t: jax.Array                # scalar i32, cohort decode step
    bits: Any = 0               # precision spec (int or (w, a) pair)
    caps_host: np.ndarray = None  # host mirror of caps (no sync needed)
    forced: jax.Array = None    # (B, n_max) forced-replay tokens: a row
                                # emits forced[i, lengths[i]] instead of
                                # its argmax while lengths[i] < n_forced[i]
                                # — the preemption-resume mechanism that
                                # keeps an already-delivered prefix exact
                                # (DESIGN.md §2.4); all-zero outside resume
    n_forced: jax.Array = None  # (B,) forced-prefix length per row

    @property
    def batch_capacity(self) -> int:
        return int(self.caps_host.shape[0])


@dataclass
class PagedDecodeState:
    """Arena-backed sibling of :class:`DecodeState` (DESIGN.md §2.3).

    The cohort's KV lives in its node-wide :class:`KVArena` — the state
    holds no cache slab, only the cohort's :class:`BlockTable` and the
    same per-row emission fields as ``DecodeState`` (so ``poll_chunked``
    / ``exhausted`` work unchanged).  Rows lease pages from the arena at
    admission and release them through ``ServingEngine.release_slots``
    the moment they complete — which is what makes freed KV from any
    cohort immediately reusable by any other cohort on the node."""
    arena: KVArena
    table: BlockTable
    cur: jax.Array              # (B,) next token to emit per row
    out: jax.Array              # (B, n_max) emitted tokens per row
    lengths: jax.Array          # (B,) emitted count per row
    done: jax.Array             # (B,) bool, EOS seen
    caps: jax.Array             # (B,) per-row output cap (0 = empty slot)
    t: jax.Array                # scalar i32, cohort decode step
    bits: Any = 0               # precision spec (int or (w, a) pair)
    caps_host: np.ndarray = None  # host mirror of caps (no sync needed)
    forced: jax.Array = None    # (B, n_max) forced-replay tokens (see
                                # DecodeState.forced)
    n_forced: jax.Array = None  # (B,) forced-prefix length per row
    # cap-aware incremental leasing (DESIGN.md §2.3): per row, one past
    # the highest block currently leased and one past the last block its
    # cap ``t0 + n`` can ever need.  Blocks in [lease_end, lease_last)
    # are TRASH in the table until a segment-boundary top-up
    # (``_extend_leases``) leases them — never mid-segment.
    lease_end: np.ndarray = None   # (B,) next block index to lease
    lease_last: np.ndarray = None  # (B,) one past last block of the cap
    t_host: int = 0             # host upper bound on ``t`` (a segment may
                                # exit early; the bound only ever
                                # OVER-covers, inside the reservation)

    @property
    def batch_capacity(self) -> int:
        return int(self.caps_host.shape[0])


def tiny_engine(arch_id: str, **engine_kw) -> "ServingEngine":
    """A CPU-sized reduced engine for ``arch_id`` (1 layer, d_model 64,
    vocab 256) — the ONE copy of the reduced-model shape the multi-engine
    benchmarks, examples and tests build their "identical reduced
    engines on both protocols" premise on.  ``engine_kw`` passes through
    to ``ServingEngine`` (``params=``, ``batch_capacity=``, ...)."""
    cfg = get_arch(arch_id).scaled(n_layers=1, d_model=64, n_heads=2,
                                   n_kv_heads=2, d_ff=128, vocab=256)
    return ServingEngine(cfg, **engine_kw)


class ServingEngine:
    """Fixed-shape batched prefill + fused-decode executor for one model."""

    def __init__(self, cfg: ModelConfig, params: Any = None,
                 batch_capacity: int = 8, s_max: int = 512,
                 n_max: int = 128, quant_bits: int = 0,
                 eos_id: int = 0, seed: int = 0,
                 use_kernel: bool = False):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.batch_capacity = batch_capacity
        self.s_max = s_max
        self.n_max = n_max
        self.eos_id = eos_id
        # route decode attention through the Pallas kernel tiers
        # (flash_decode / flash_decode_fused when the served tree is
        # fusable) instead of the XLA gather path; only the transformer
        # families' decode steps accept the flag
        if use_kernel and cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"use_kernel=True needs a transformer-family model "
                f"(dense/moe/vlm), got family {cfg.family!r}")
        self.use_kernel = bool(use_kernel)
        self._decode_kw = {"use_kernel": True} if use_kernel else {}
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self._raw_params = params            # full precision master copy
        self._params_cache: dict = {}        # weight_bits -> param tree
        self.default_bits = self._canon_bits(quant_bits)
        self.params = self.params_for(quant_bits)
        self.precisions_served: set = set()  # bit-widths generate() ran at
        self.cache_len = s_max + n_max
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)
        # the fused decode loop consumes the prefill cache in place; CPU
        # does not implement donation (it would only warn), so gate it
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._decode_loop = jax.jit(self._decode_loop_fn,
                                    donate_argnums=donate)
        # chunked decode: the segment loop consumes the carried state
        # (cache, cur, out, lengths, done) — argnums 1-5 — and the refill
        # merge consumes the old cache it splices the new slots into
        seg_donate = (1, 2, 3, 4, 5) if donate else ()
        self._decode_chunk = jax.jit(self._decode_chunk_fn,
                                     donate_argnums=seg_donate)
        self._refill_merge = jax.jit(self._refill_merge_fn,
                                     donate_argnums=(0,) if donate else ())
        # paged path (DESIGN.md §2.3): the segment loop consumes the
        # arena page buffers + per-row emission state; the block-scatter
        # consumes the old pages AND the contiguous prefill cache it
        # splices in
        self._decode_chunk_paged = jax.jit(
            self._decode_chunk_paged_fn,
            donate_argnums=(1, 3, 4, 5, 6) if donate else ())
        self._page_scatter = jax.jit(
            self._page_scatter_fn,
            donate_argnums=(0, 1) if donate else ())
        self._refill_rows = jax.jit(self._refill_rows_fn)
        self._cache_axes = None              # per-leaf batch axis (lazy)
        self.lease_topups = 0                # pages leased via segment-
                                             # boundary top-up (metrics)

    # -- multi-precision weight cache ---------------------------------------

    @staticmethod
    def _canon_bits(bits):
        """Canonical precision spec.

        Accepts an int (weight bits; 0/16 both mean full precision) or a
        ``(weight_bits, act_bits)`` pair (a QuantMethod.serve_bits — W8A8
        serves as ``(8, 8)``).  On interpret backends the activation tag
        is canonicalized away — quantized trees are dequantized at load
        there (see ``params_for``), so (8, 8) and 8 would be the same
        tree and must share one cache entry."""
        if isinstance(bits, (tuple, list)):
            w, a = bits
            w = 0 if not w or w >= 16 else int(w)
            a = 16 if not a or a >= 16 else int(a)
            if w == 0 or a == 16 or _INTERPRET:
                return w
            return (w, a)
        return 0 if not bits or bits >= 16 else int(bits)

    def params_for(self, bits):
        """Weights at ``bits`` precision (int or (w, a) pair), quantized
        once and cached so the scheduler can swap the served method every
        epoch.  On TPU, dense/moe/vlm trees keep their QTensor leaves and
        serve through the Pallas kernel tiers (W8A16/W4A16, W8A8 when
        tagged act_bits=8).  On interpret backends EVERY family
        dequantizes at load: int8 compute cannot beat the f32 BLAS there
        (measured, DESIGN.md §3), so quantized serving keeps fake-quant
        numerics but runs fp-speed XLA matmuls — quantization pays in
        bytes and on TPU, never as an interpret-mode slowdown."""
        bits = self._canon_bits(bits)
        if bits not in self._params_cache:
            if bits == 0:
                p = self._raw_params
            else:
                w, a = bits if isinstance(bits, tuple) else (bits, 16)
                p = quantize_tree(self._raw_params, w, act_bits=a)
                if self.cfg.family not in ("dense", "moe", "vlm") \
                        or _INTERPRET:
                    # recurrent/encdec matmuls don't route through
                    # common.mm; interpret backends serve dequantized
                    p = dequantize_tree(p)
            self._params_cache[bits] = p
        return self._params_cache[bits]

    def decode_tier(self, bits=None) -> str:
        """The Pallas decode-attention tier ``use_kernel=True`` serving
        at ``bits`` (engine default when None) routes to — ``"kv8"`` /
        ``"fused"`` / ``"flash"``, see ``kernels.ops.decode_kernel_tier``.
        Interpret backends dequantize quantized trees at load, so they
        report ``"flash"`` even for int8 methods."""
        from repro.kernels import ops as kops
        params = self.params_for(self.default_bits if bits is None
                                 else bits)
        layer = params.get("layers", params) if isinstance(params, dict) \
            else params
        return kops.decode_kernel_tier(layer, self.cfg)

    # -- compiled step functions --------------------------------------------

    def _prefill_fn(self, params, batch):
        """Prompt pass; returns (first sampled token (B,), KV cache)."""
        logits, cache = self.model.prefill(params, batch, self.cache_len)
        cur = jnp.argmax(logits[..., :self.cfg.vocab], -1).astype(jnp.int32)
        return cur, cache

    def _decode_fn(self, params, cache, tokens, pos):
        return self.model.decode_step(params, cache, tokens, pos,
                                      **self._decode_kw)

    def _decode_loop_fn(self, params, cache, cur, caps):
        """The entire autoregressive stage as ONE ``lax.while_loop``.

        Carries ``(cache, cur, out, lengths, done, t)`` on device; emits
        ``cur`` into ``out[:, t]`` for rows still alive (not done, under
        cap), flags EOS rows, steps the model, and exits as soon as no row
        can emit again.  Mirrors ``generate_reference`` bit for bit: dead
        rows keep stepping through the model (their cache writes are
        irrelevant — they never emit again), exactly like the legacy loop.
        """
        B = cur.shape[0]
        out0 = jnp.zeros((B, self.n_max), jnp.int32)
        lengths0 = jnp.zeros((B,), jnp.int32)
        done0 = jnp.zeros((B,), bool)

        def alive_mask(done, t):
            return (~done) & (t < caps)

        def cond(state):
            _, _, _, _, done, t = state
            return (t < self.n_max) & jnp.any(alive_mask(done, t))

        def body(state):
            cache, cur, out, lengths, done, t = state
            alive = alive_mask(done, t)
            out = out.at[:, t].set(jnp.where(alive, cur, out[:, t]))
            lengths = lengths + alive.astype(jnp.int32)
            done = done | ((cur == self.eos_id) & alive)
            logits, cache = self.model.decode_step(
                params, cache, cur[:, None], self.s_max + t,
                **self._decode_kw)
            cur = jnp.argmax(logits[..., :self.cfg.vocab],
                             -1).astype(jnp.int32)
            return cache, cur, out, lengths, done, t + 1

        state = (cache, cur, out0, lengths0, done0, jnp.int32(0))
        _, _, out, lengths, _, _ = jax.lax.while_loop(cond, body, state)
        return out, lengths

    def _decode_chunk_fn(self, params, cache, cur, out, lengths, done,
                         caps, t, t_end, forced, n_forced):
        """One re-entrant SEGMENT of the fused decode loop.

        Identical per-step ops to ``_decode_loop_fn``, but (a) the carried
        state enters and leaves as arguments so the loop can be resumed,
        and (b) rows emit at their own ``lengths[i]`` instead of the
        cohort step ``t`` — equal while every row started at t=0 (which
        makes chunked decode bit-identical to the single fused loop), and
        what lets rows admitted mid-cohort by ``refill_chunked`` fill
        their row of ``out`` from 0.  ``t_end`` bounds this segment;
        passing it as an operand keeps ONE compiled executable for every
        chunk size k.

        While ``lengths[i] < n_forced[i]`` a row emits (and feeds the
        model) ``forced[i, lengths[i]]`` instead of its argmax — the
        preempt-resume replay: a resumed row re-prefills its ORIGINAL
        prompt and replays the tokens it already delivered, pinning the
        user-visible prefix bit-exactly regardless of the cohort
        alignment it rejoins at (DESIGN.md §2.4).  ``n_forced`` is zero
        outside resume, making the override a no-op.
        """
        B = cur.shape[0]
        rows = jnp.arange(B)

        def alive_mask(done, lengths):
            return (~done) & (lengths < caps)

        def cond(state):
            _, _, _, lengths, done, t = state
            return (t < t_end) & jnp.any(alive_mask(done, lengths))

        def body(state):
            cache, cur, out, lengths, done, t = state
            alive = alive_mask(done, lengths)
            idx = jnp.minimum(lengths, self.n_max - 1)
            cur = jnp.where(lengths < n_forced, forced[rows, idx], cur)
            out = out.at[rows, idx].set(
                jnp.where(alive, cur, out[rows, idx]))
            lengths = lengths + alive.astype(jnp.int32)
            done = done | ((cur == self.eos_id) & alive)
            logits, cache = self.model.decode_step(
                params, cache, cur[:, None], self.s_max + t,
                **self._decode_kw)
            cur = jnp.argmax(logits[..., :self.cfg.vocab],
                             -1).astype(jnp.int32)
            return cache, cur, out, lengths, done, t + 1

        state = (cache, cur, out, lengths, done, t)
        return jax.lax.while_loop(cond, body, state)

    def _cache_batch_axes(self):
        """Per-leaf batch axis of the cache pytree (recurrent families put
        scan-stacked leading dims before batch), derived structurally by
        diffing cache shapes at two batch sizes — no family-specific
        layout knowledge."""
        if self._cache_axes is None:
            a = jax.eval_shape(lambda: self.model.init_cache(2,
                                                             self.cache_len))
            b = jax.eval_shape(lambda: self.model.init_cache(3,
                                                             self.cache_len))

            def axis(sa, sb):
                diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                        if x != y]
                assert len(diff) == 1, (sa.shape, sb.shape)
                return diff[0]

            self._cache_axes = jax.tree_util.tree_map(axis, a, b)
        return self._cache_axes

    def _refill_merge_fn(self, old_cache, new_cache, cur, new_cur, out,
                         lengths, done, caps, new_caps, refill):
        """Splice freshly prefilled rows into a live decode state.

        ``refill`` is the (B,) bool slot mask; refilled rows take the new
        prefill's cache/cur and reset their emission state, live rows are
        untouched."""
        axes = self._cache_batch_axes()

        def mix(ax, old, new):
            m = refill.reshape((1,) * ax + (-1,)
                               + (1,) * (old.ndim - ax - 1))
            return jnp.where(m, new, old)

        cache = jax.tree_util.tree_map(
            lambda ax, o, n: mix(ax, o, n), axes, old_cache, new_cache)
        cur = jnp.where(refill, new_cur, cur)
        out = jnp.where(refill[:, None], 0, out)
        lengths = jnp.where(refill, 0, lengths)
        done = jnp.where(refill, False, done)
        caps = jnp.where(refill, new_caps, caps)
        return cache, cur, out, lengths, done, caps

    # -- paged-arena compiled step functions (DESIGN.md §2.3) ----------------

    def _decode_chunk_paged_fn(self, params, pages, table, cur, out,
                               lengths, done, caps, t, t_end, forced,
                               n_forced):
        """The re-entrant decode segment over the PAGED cache: identical
        per-step ops to ``_decode_chunk_fn`` (including the forced-replay
        override) but the KV reads/writes go through
        ``model.decode_step_paged`` — the node-wide page buffers
        are the carried cache and the cohort's block table (static within
        a segment; rows only change at admission/release boundaries) is
        an operand."""
        B = cur.shape[0]
        rows = jnp.arange(B)

        def alive_mask(done, lengths):
            return (~done) & (lengths < caps)

        def cond(state):
            _, _, _, lengths, done, t = state
            return (t < t_end) & jnp.any(alive_mask(done, lengths))

        def body(state):
            pages, cur, out, lengths, done, t = state
            alive = alive_mask(done, lengths)
            idx = jnp.minimum(lengths, self.n_max - 1)
            cur = jnp.where(lengths < n_forced, forced[rows, idx], cur)
            out = out.at[rows, idx].set(
                jnp.where(alive, cur, out[rows, idx]))
            lengths = lengths + alive.astype(jnp.int32)
            done = done | ((cur == self.eos_id) & alive)
            logits, pages = self.model.decode_step_paged(
                params, pages, table, cur[:, None], self.s_max + t,
                **self._decode_kw)
            cur = jnp.argmax(logits[..., :self.cfg.vocab],
                             -1).astype(jnp.int32)
            return pages, cur, out, lengths, done, t + 1

        state = (pages, cur, out, lengths, done, t)
        return jax.lax.while_loop(cond, body, state)

    def _page_scatter_fn(self, pages, cache, ids):
        """Splice a contiguous prefill cache into the arena, block-wise.

        ``ids`` is (B * n_blocks,) int32: the physical page receiving
        logical block (b, j) — ``TRASH_PAGE`` for rows/blocks that were
        not (re)filled, so their scatter lands in the don't-care page
        (duplicate trash indices are benign: nothing live reads it).
        Page tails can exceed this engine's cache tail (node pool sized
        to the max over cohorts) — the scatter fills only the leading
        corner, matching the reads in ``decode_attention_paged``."""
        out = {}
        for name, pleaf in pages.items():
            cleaf = cache[name]
            L, B, W = cleaf.shape[:3]
            bt = pleaf.shape[2]
            vals = cleaf.reshape((L, B * (W // bt), bt) + cleaf.shape[3:])
            idx = (slice(None), ids, slice(None)) \
                + tuple(slice(0, d) for d in vals.shape[3:])
            out[name] = pleaf.at[idx].set(vals.astype(pleaf.dtype))
        return out

    def _refill_rows_fn(self, cur, new_cur, out, lengths, done, caps,
                        new_caps, refill):
        """Per-row emission-state splice of a paged refill (the cache
        splice happened in ``_page_scatter_fn``)."""
        cur = jnp.where(refill, new_cur, cur)
        out = jnp.where(refill[:, None], 0, out)
        lengths = jnp.where(refill, 0, lengths)
        done = jnp.where(refill, False, done)
        caps = jnp.where(refill, new_caps, caps)
        return cur, out, lengths, done, caps

    # -- public API ----------------------------------------------------------

    def synth_prompts(self, requests: Sequence, rng: np.random.Generator):
        """Synthesize random-token prompts + output caps for scheduled
        requests, clamped to this engine's static shapes (the cost-model
        lengths s_i/n_i may exceed a reduced engine's s_max/n_max)."""
        prompts = [rng.integers(1, self.cfg.vocab,
                                size=min(r.s, self.s_max)).tolist()
                   for r in requests]
        caps = [min(r.n, self.n_max) for r in requests]
        return prompts, caps

    def pad_prompts(self, prompts: Sequence[Sequence[int]]) -> np.ndarray:
        """Left-truncate/right-pad prompts to (batch_capacity, s_max)."""
        B = self.batch_capacity
        out = np.zeros((B, self.s_max), np.int32)
        for i, p in enumerate(prompts[:B]):
            p = list(p)[-self.s_max:]
            out[i, -len(p):] = p        # right-aligned => last slot is last
        return out

    def _prepare(self, prompts, n_tokens, quant_bits):
        """Shared generate() front half: resolve weights, pad the batch and
        ship (prompts, caps) to the device in ONE ``jax.device_put``."""
        bits = self.default_bits if quant_bits is None \
            else self._canon_bits(quant_bits)
        params = self.params_for(bits)
        self.precisions_served.add(bits)
        return (params, bits) + self._pad_and_ship(prompts, n_tokens)

    def _pad_and_ship(self, prompts, n_tokens):
        B = self.batch_capacity
        nb = len(prompts)
        assert nb <= B, (nb, B)
        caps = np.full((B,), self.n_max, np.int32)
        if n_tokens is not None:
            caps[:nb] = np.minimum(np.asarray(n_tokens, np.int32), self.n_max)
        caps[nb:] = 0

        tokens, caps_j = jax.device_put((self.pad_prompts(prompts), caps))
        return self._as_batch(tokens), caps_j, caps, nb

    def _as_batch(self, tokens):
        """Wrap device-resident prompt tokens as a model input batch."""
        B = self.batch_capacity
        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.vlm.n_img_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (B, self.cfg.encdec.n_audio_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return batch

    def generate(self, prompts: Sequence[Sequence[int]],
                 n_tokens: Optional[Sequence[int]] = None,
                 greedy: bool = True,
                 quant_bits: Optional[int] = None) -> GenerationResult:
        """Prefill + fused device-resident decode of one batch.

        ``n_tokens`` caps each request's output; ``quant_bits`` serves this
        batch at an explicit weight precision (via the multi-precision
        cache), ``None`` uses the engine default.  Exactly one
        host→device and one device→host transfer happen per call — every
        token decision (sampling, EOS, caps) stays on device inside
        ``_decode_loop_fn``.
        """
        params, _, batch, caps_j, _, nb = self._prepare(prompts, n_tokens,
                                                        quant_bits)
        cur, cache = self._prefill(params, batch)
        out_j, lengths_j = self._decode_loop(params, cache, cur, caps_j)
        out, lengths = jax.device_get((out_j, lengths_j))
        return GenerationResult(tokens=out[:nb], lengths=lengths[:nb],
                                batch=nb)

    def generate_reference(self, prompts: Sequence[Sequence[int]],
                           n_tokens: Optional[Sequence[int]] = None,
                           greedy: bool = True,
                           quant_bits: Optional[int] = None
                           ) -> GenerationResult:
        """The legacy host-driven decode loop, kept as the interpret-style
        oracle: one blocking device→host transfer PER TOKEN.  The fused
        path must match it bit for bit (see tests/test_serving.py)."""
        params, _, batch, _, caps, nb = self._prepare(prompts, n_tokens,
                                                      quant_bits)
        B = self.batch_capacity
        cur_j, cache = self._prefill(params, batch)
        cur = np.asarray(jax.device_get(cur_j), np.int32)

        out = np.zeros((B, self.n_max), np.int32)
        lengths = np.zeros((B,), np.int32)
        done = np.zeros((B,), bool)

        for t in range(int(caps.max(initial=0))):
            alive = (~done) & (t < caps)
            if not alive.any():
                break
            out[alive, t] = cur[alive]
            lengths[alive] += 1
            done |= (cur == self.eos_id) & alive
            step_tok = jnp.asarray(cur)[:, None]
            pos = jnp.int32(self.s_max + t)
            logits, cache = self._decode(params, cache, step_tok, pos)
            cur = np.asarray(
                jax.device_get(
                    jnp.argmax(logits[..., :self.cfg.vocab], -1)), np.int32)
        return GenerationResult(tokens=out[:nb], lengths=lengths[:nb],
                                batch=nb)

    # -- chunked (re-entrant) decode: the continuous-batching data plane ----

    @property
    def paged_capable(self) -> bool:
        """Whether this engine's family can serve through a paged KV
        arena: a slot-cache layout with no rolling sliding window (page
        identity must be position-stable) and a paged decode step.  MoE
        is excluded: capacity dispatch couples rows, so a released row's
        trash-page garbage could perturb live rows' expert routing — the
        per-row independence the bit-exactness contract relies on."""
        return self.model.decode_step_paged is not None \
            and not self.cfg.sliding_window and not self.cfg.is_moe

    def pages_for_admission(self, t: int, n: int,
                            block_tokens: int) -> int:
        """Pages one row admitted at cohort step ``t`` with output cap
        ``n`` will lease over its whole life — CAP-AWARE, not worst-case.

        The row's writes land at the cohort-shared position ``s_max + τ``
        for ``τ in [t, min(t + n, n_max))``, so it needs exactly its
        prompt-prefix blocks plus the blocks covering that write span:
        the fully-dead junk-gap blocks ``[ceil(s_max/bt), (s_max+t)//bt)``
        map to the shared zero page and cost nothing, and blocks past the
        cap's last write block are NEVER leased — any overflow write
        (a finished row keeps stepping until released) routes to
        ``TRASH_PAGE`` through the block table.  Admission (``accepts``)
        reserves this count; ``start/refill_chunked`` lease the prompt
        prefix + first write block up front and ``_extend_leases`` tops
        the rest up at segment boundaries, so the reservation equals the
        pages subsequently leased (tests pin the identity) and a row
        never writes an unleased block WITHIN a segment."""
        nb = self.cache_len // block_tokens
        t = max(0, int(t))
        end = min(t + int(n), self.n_max)
        if end <= t:
            return 0            # no headroom / cap 0: nothing to lease
        npb = -(-self.s_max // block_tokens)
        b_w = min((self.s_max + t) // block_tokens, nb - 1)
        b_last = (self.s_max + end - 1) // block_tokens
        return npb + max(0, b_last + 1 - max(npb, b_w))

    def _lease_row(self, arena: KVArena, t: int, cap: int):
        """Initial cap-aware lease plan for one row admitted at cohort
        step ``t`` with output cap ``cap``: the blocks to lease NOW
        (prompt prefix + the first write block, which must be scattered
        from the prefill cache so the gap-tail positions inside it read
        as the slab's zeros), the table row mapping (ZERO for the
        fully-dead junk gap, TRASH beyond the lease span), and the
        ``(lease_end, lease_last)`` bookkeeping the segment-boundary
        top-up advances."""
        bt = arena.block_tokens
        nb = self.cache_len // bt
        npb = -(-self.s_max // bt)
        b_w = min((self.s_max + int(t)) // bt, nb - 1)
        row = np.full((nb,), TRASH_PAGE, np.int32)
        row[npb:b_w] = ZERO_PAGE        # junk gap [s_max, s_max + t)
        blocks = list(range(npb))
        if b_w >= npb:
            blocks.append(b_w)
        lease_end = b_w + 1 if b_w >= npb else npb
        end = min(int(t) + int(cap), self.n_max)
        b_last = (self.s_max + end - 1) // bt if end > int(t) else 0
        lease_last = max(lease_end, b_last + 1)
        return blocks, row, lease_end, lease_last

    def _extend_leases(self, state: PagedDecodeState, k: int) -> None:
        """Segment-boundary lease top-up (DESIGN.md §2.3): before a
        segment of at most ``k`` steps launches, every row's lease must
        cover the blocks the segment can write — a block is read
        UNMASKED once the cursor passes it, so it must be leased before
        the cursor enters it, never after.  Host-side ``BlockTable``
        remap + ONE device re-ship (the lazy mirror), never a
        mid-segment allocation.  ``t_host`` is a host-side upper bound
        on the cohort step (segments may exit early), so the cover can
        only OVERSHOOT — bounded by ``lease_last``, i.e. inside the
        admission-time reservation the runtime charged."""
        arena = state.arena
        bt = arena.block_tokens
        nb = self.cache_len // bt
        cover = min(state.t_host + int(k), self.n_max)
        need_end = min((self.s_max + cover - 1) // bt + 1, nb)
        for b in range(state.lease_end.shape[0]):
            tgt = min(need_end, int(state.lease_last[b]))
            le = int(state.lease_end[b])
            if tgt > le:
                state.table.extend_row(b, le, arena.alloc(tgt - le))
                state.lease_end[b] = tgt
                self.lease_topups += tgt - le
        state.t_host = cover

    def lease_commitment(self, state: Optional[PagedDecodeState]) -> int:
        """Pages a live cohort is still ENTITLED to lease via future
        top-ups (Σ ``lease_last - lease_end``).  Admission must keep
        this many pages un-promised on top of the free list, so a
        boundary's top-ups can never hit :class:`ArenaExhausted`."""
        if state is None or state.lease_end is None:
            return 0
        return int(np.maximum(0, state.lease_last.astype(np.int64)
                              - state.lease_end).sum())

    def _forced_buffers(self, prefixes, slots=None):
        """Host (B, n_max) forced-replay token buffer + (B,) lengths from
        per-row resume prefixes (``None`` entries = no replay).  ``slots``
        maps prefix i to its row (defaults to ``0..len-1``)."""
        B = self.batch_capacity
        forced = np.zeros((B, self.n_max), np.int32)
        nf = np.zeros((B,), np.int32)
        if prefixes is not None:
            rows = range(len(prefixes)) if slots is None else slots
            for row, pre in zip(rows, prefixes):
                if pre is not None and len(pre):
                    pre = list(pre)[:self.n_max]
                    forced[row, :len(pre)] = pre
                    nf[row] = len(pre)
        return forced, nf

    def start_chunked(self, prompts: Sequence[Sequence[int]],
                      n_tokens: Optional[Sequence[int]] = None,
                      quant_bits: Optional[int] = None,
                      arena: Optional[KVArena] = None,
                      prefixes: Optional[Sequence] = None):
        """Prefill a new cohort and return its device-resident decode
        state (ONE host→device transfer; decoding hasn't started).
        Prompts occupy slots ``0..len(prompts)-1``; the remaining slots
        are empty (cap 0) and refillable.  With ``arena=`` the cohort is
        arena-backed: the prefill cache is scattered block-wise into
        leased pages and a :class:`PagedDecodeState` is returned.
        ``prefixes`` seeds per-row forced-replay tokens (one entry per
        prompt, ``None`` = fresh row) for preemption resume — see
        ``_decode_chunk_fn``."""
        params, bits, batch, caps_j, caps, _ = self._prepare(
            prompts, n_tokens, quant_bits)
        cur, cache = self._prefill(params, batch)
        B = self.batch_capacity
        if prefixes is None:       # keep the one-put-at-start invariant
            forced = jnp.zeros((B, self.n_max), jnp.int32)
            nf = jnp.zeros((B,), jnp.int32)
        else:
            forced, nf = jax.device_put(self._forced_buffers(prefixes))
        if arena is None:
            return DecodeState(
                cache=cache, cur=cur,
                out=jnp.zeros((B, self.n_max), jnp.int32),
                lengths=jnp.zeros((B,), jnp.int32),
                done=jnp.zeros((B,), bool),
                caps=caps_j, t=jnp.int32(0), bits=bits, caps_host=caps,
                forced=forced, n_forced=nf)
        assert self.paged_capable, self.cfg.arch_id
        bt = arena.block_tokens
        assert self.cache_len % bt == 0, (self.cache_len, bt)
        nb = self.cache_len // bt
        table = BlockTable(B, nb, n_pages=arena.n_pages)
        ids = np.full((B * nb,), TRASH_PAGE, np.int32)
        lease_end = np.zeros((B,), np.int32)
        lease_last = np.zeros((B,), np.int32)
        for b in range(B):
            if caps[b] > 0:
                # cap-aware lease: prompt blocks + first write block now
                # (blocks past it stay TRASH until a segment-boundary
                # top-up), instead of the historical full-span alloc(nb)
                blocks, row, le, ll = self._lease_row(arena, 0, caps[b])
                leases = arena.alloc(len(blocks))
                row[blocks] = leases
                table.set_row(b, row)
                ids[b * nb + np.asarray(blocks)] = leases
                lease_end[b], lease_last[b] = le, ll
        pages = self._page_scatter(arena.buffers(), cache,
                                   jax.device_put(ids))
        arena.set_buffers(pages)
        return PagedDecodeState(
            arena=arena, table=table, cur=cur,
            out=jnp.zeros((B, self.n_max), jnp.int32),
            lengths=jnp.zeros((B,), jnp.int32),
            done=jnp.zeros((B,), bool),
            caps=caps_j, t=jnp.int32(0), bits=bits, caps_host=caps,
            forced=forced, n_forced=nf,
            lease_end=lease_end, lease_last=lease_last, t_host=0)

    def generate_chunked(self, state, k: int):
        """Advance a cohort by AT MOST ``k`` decode steps (one jitted
        re-entrant while-loop segment, no host transfer) and return the
        re-entrant state.  The input state is consumed (donated on
        backends that support it).  Driven to completion this is
        bit-identical to the single fused loop for any k (see
        tests/test_continuous_engine.py).  A :class:`PagedDecodeState`
        advances through the paged segment loop — the arena page buffers
        are checked out, carried through the while-loop, and checked
        back in."""
        params = self.params_for(state.bits)
        t_end = jnp.minimum(state.t + jnp.int32(k), jnp.int32(self.n_max))
        if isinstance(state, PagedDecodeState):
            # boundary top-up: lease every block this segment can write
            # BEFORE launching it (one host-side remap + one table
            # re-ship; the jitted segment never allocates)
            self._extend_leases(state, k)
            pages, cur, out, lengths, done, t = self._decode_chunk_paged(
                params, state.arena.buffers(), state.table.device,
                state.cur, state.out, state.lengths, state.done,
                state.caps, state.t, t_end, state.forced, state.n_forced)
            state.arena.set_buffers(pages)
            return dataclasses.replace(state, cur=cur, out=out,
                                       lengths=lengths, done=done, t=t)
        cache, cur, out, lengths, done, t = self._decode_chunk(
            params, state.cache, state.cur, state.out, state.lengths,
            state.done, state.caps, state.t, t_end, state.forced,
            state.n_forced)
        return dataclasses.replace(state, cache=cache, cur=cur, out=out,
                                   lengths=lengths, done=done, t=t)

    def release_slots(self, state: PagedDecodeState,
                      slots: Sequence[int]) -> PagedDecodeState:
        """Return completed rows' page leases to the arena and remap
        their table rows to the trash page (their continued writes — dead
        rows keep stepping, exactly like the slab path — become
        don't-care scatters no live row reads).  Freed pages are
        allocatable by ANY cohort at the very next admission boundary,
        and the row's remaining lease entitlement is CANCELLED — the
        un-leased tail of its reservation returns to the node's
        admission budget (``lease_commitment``) the same moment."""
        for slot in slots:
            state.arena.free(state.table.row_leases(slot))
            state.table.clear_row(slot)
            if state.lease_end is not None:
                state.lease_end[slot] = 0
                state.lease_last[slot] = 0
        return state

    def release_all(self, state: PagedDecodeState) -> PagedDecodeState:
        """Release every leased page of a drained cohort."""
        return self.release_slots(state,
                                  range(state.table.host.shape[0]))

    def poll_chunked(self, state: DecodeState, with_tokens: bool = True):
        """Read a cohort's progress back to the host: ONE device→host
        transfer returning ``(out, lengths, done, t)`` as numpy + int.

        ``with_tokens=False`` skips the (B, n_max) token buffer — the
        per-segment hot path (``EngineContinuousExecutor``) only needs
        the few-hundred-byte ``(lengths, done, t)`` occupancy view, and
        at production shapes ``out`` is the dominant transfer; ``out``
        comes back as None."""
        if not with_tokens:
            lengths, done, t = jax.device_get(
                (state.lengths, state.done, state.t))
            return None, lengths, done, int(t)
        out, lengths, done, t = jax.device_get(
            (state.out, state.lengths, state.done, state.t))
        return out, lengths, done, int(t)

    def exhausted(self, lengths, done, caps_host, t) -> bool:
        """True when no row of a polled cohort can emit again."""
        return t >= self.n_max or \
            not bool(np.any(~done & (lengths < caps_host)))

    def headroom(self, t: int) -> int:
        """Output tokens a row admitted at cohort step ``t`` can still
        emit before the shared cache position hits capacity."""
        return max(0, self.n_max - t)

    def evict_slots(self, state, slots: Sequence[int]):
        """Preempt resident rows at a segment boundary: flag them done
        and zero their caps so the next segment treats them exactly like
        finished rows (dead rows keep stepping; their writes are
        don't-care scatters).  Paged rows additionally return their page
        leases, so the freed memory is allocatable at the very next
        admission boundary.  The caller is responsible for having
        polled any progress it wants to spill BEFORE evicting."""
        slots = list(slots)
        if not slots:
            return state
        B = self.batch_capacity
        mask = np.zeros((B,), bool)
        mask[slots] = True
        mask_j = jax.device_put(mask)
        done = jnp.where(mask_j, True, state.done)
        caps = jnp.where(mask_j, 0, state.caps)
        caps_host = np.where(mask, 0, state.caps_host)
        if isinstance(state, PagedDecodeState):
            for slot in slots:
                state.arena.free(state.table.row_leases(slot))
                state.table.clear_row(slot)
                if state.lease_end is not None:
                    state.lease_end[slot] = 0     # cancel the remaining
                    state.lease_last[slot] = 0    # lease entitlement too
        return dataclasses.replace(state, done=done, caps=caps,
                                   caps_host=caps_host)

    def refill_chunked(self, state, slots: Sequence[int],
                       prompts: Sequence[Sequence[int]],
                       n_tokens: Sequence[int], t_now: int,
                       cap_max: Optional[int] = None,
                       prefixes: Optional[Sequence] = None):
        """Prefill new prompts into freed slots of a LIVE cohort.

        The new prompts are padded into their slot rows, prefilled as one
        full-capacity batch (positions ``[0, s_max)`` — one device_put +
        one compiled prefill), and spliced into the resident cache with
        ``_refill_merge`` so live rows keep decoding untouched.  A
        refilled row's cap is clamped to ``headroom(t_now)`` so its cache
        writes stay inside ``s_max + n_max``; callers gate admission on
        that headroom.  ``cap_max`` tightens the clamp further (an
        explicit caller-side bound; admission control normally makes it
        redundant with the cohort's own headroom).  When the clamp
        bottoms out at 0 — or ``slots`` is empty — the refill is a
        NO-OP returning ``state`` untouched: prefilling rows that could
        never emit would occupy slots until drain for nothing.  Cache
        slots between a refilled row's prompt and the cohort's current
        position hold zero K/V — junk attention positions of the same
        class as the engine's padded prompts (the paper's s' padding);
        recurrent-state families have no such gap.  For a
        :class:`PagedDecodeState` the splice is block-wise and
        CAP-AWARE: fresh pages are leased for the prompt blocks plus the
        first write block only, the fully-dead junk-gap blocks map to
        the shared zero page (no physical memory), and the rest of the
        row's ``t + n`` span stays TRASH until the segment-boundary
        top-up leases it (DESIGN.md §2.3).
        """
        B = self.batch_capacity
        params = self.params_for(state.bits)
        toks = np.zeros((B, self.s_max), np.int32)
        new_caps = np.zeros((B,), np.int32)
        refill = np.zeros((B,), bool)
        cap_lim = min(self.n_max, self.headroom(t_now))
        if cap_max is not None:
            cap_lim = min(cap_lim, max(0, int(cap_max)))
        if not slots or cap_lim <= 0:
            return state
        for slot, p, n in zip(slots, prompts, n_tokens):
            p = list(p)[-self.s_max:]
            if p:
                toks[slot, -len(p):] = p
            new_caps[slot] = min(int(n), cap_lim)
            refill[slot] = True
        toks_j, caps_j, refill_j = jax.device_put((toks, new_caps, refill))
        new_cur, new_cache = self._prefill(params, self._as_batch(toks_j))
        caps_host = np.where(refill, new_caps, state.caps_host)
        # Forced-replay splice (preemption resume): refilled rows take
        # their resume prefix (or reset to no-replay); live rows keep
        # theirs.  Outside the jitted merges — it's a few KB — and the
        # no-resume path skips the extra transfer entirely.
        if prefixes is None:
            forced = jnp.where(refill_j[:, None], 0, state.forced)
            n_forced = jnp.where(refill_j, 0, state.n_forced)
        else:
            forced_j, nf_j = jax.device_put(
                self._forced_buffers(prefixes, slots=slots))
            forced = jnp.where(refill_j[:, None], forced_j, state.forced)
            n_forced = jnp.where(refill_j, nf_j, state.n_forced)
        if isinstance(state, PagedDecodeState):
            arena = state.arena
            bt = arena.block_tokens
            nb = self.cache_len // bt
            ids = np.full((B * nb,), TRASH_PAGE, np.int32)
            for slot in slots:
                arena.free(state.table.row_leases(slot))  # stale leases
                # cap-aware lease: prompt blocks + the first write block
                # (scattered so its gap-tail positions read as the
                # slab's zeros); the junk gap maps to ZERO, everything
                # past the first write block stays TRASH until the
                # segment-boundary top-up reaches it
                blocks, row, le, ll = self._lease_row(
                    arena, t_now, new_caps[slot])
                leases = arena.alloc(len(blocks))
                row[blocks] = leases
                state.table.set_row(slot, row)
                ids[slot * nb + np.asarray(blocks)] = leases
                state.lease_end[slot] = le
                state.lease_last[slot] = ll
            pages = self._page_scatter(arena.buffers(), new_cache,
                                       jax.device_put(ids))
            arena.set_buffers(pages)
            cur, out, lengths, done, caps = self._refill_rows(
                state.cur, new_cur, state.out, state.lengths, state.done,
                state.caps, caps_j, refill_j)
            return dataclasses.replace(state, cur=cur, out=out,
                                       lengths=lengths, done=done,
                                       caps=caps, caps_host=caps_host,
                                       forced=forced, n_forced=n_forced,
                                       t_host=int(t_now))
        cache, cur, out, lengths, done, caps = self._refill_merge(
            state.cache, new_cache, state.cur, new_cur, state.out,
            state.lengths, state.done, state.caps, caps_j, refill_j)
        return dataclasses.replace(state, cache=cache, cur=cur, out=out,
                                   lengths=lengths, done=done, caps=caps,
                                   caps_host=caps_host,
                                   forced=forced, n_forced=n_forced)

    def generate_via_chunks(self, prompts: Sequence[Sequence[int]],
                            n_tokens: Optional[Sequence[int]] = None,
                            k: Optional[int] = None,
                            quant_bits: Optional[int] = None,
                            arena: Optional[KVArena] = None
                            ) -> GenerationResult:
        """Drive ``start_chunked`` + ``generate_chunked`` segments to
        completion — the equivalence harness against ``generate`` /
        ``generate_reference`` (one device→host poll per segment).  With
        ``arena=`` the cohort runs arena-backed (and its pages are
        released on completion) — the paged-vs-slab equivalence oracle."""
        k = self.n_max if k is None else k
        state = self.start_chunked(prompts, n_tokens, quant_bits,
                                   arena=arena)
        while True:
            state = self.generate_chunked(state, k)
            out, lengths, done, t = self.poll_chunked(state)
            if self.exhausted(lengths, done, state.caps_host, t):
                break
        if arena is not None:
            self.release_all(state)
        nb = len(prompts)
        return GenerationResult(tokens=out[:nb], lengths=lengths[:nb],
                                batch=nb)
