"""Batched-inference engine: executes scheduled batches on the real JAX model.

This is the data plane behind the paper's scheduler (the control plane).
A scheduled batch of prompts is padded to the epoch's s' (exactly the
paper's 'extend all prompts to the maximum length' assumption), prefilled
in one pass, then decoded by a single **device-resident**
``jax.lax.while_loop``: greedy sampling, EOS detection and per-request
output caps are all ``jnp`` ops inside one compiled program, which exits
early once every row is done.  The host never sees a token until the
whole batch finishes — per ``generate`` call there is exactly ONE
host→device transfer (the padded prompts + caps, a single
``jax.device_put``) and ONE device→host transfer (the token buffer +
lengths, a single ``jax.device_get``).  The KV cache produced by prefill
is donated into the decode-loop executable (``donate_argnums``, on
backends that support donation) so the loop carries it in place instead
of copying it at entry.  The historical token-by-token Python loop — one
blocking ``argmax`` transfer per token — survives only as
``generate_reference``, the interpret-style oracle the equivalence tests
compare against.

Static shapes: (batch_capacity, s') for prefill and a KV cache capacity of
s' + n_max — one compiled executable serves every epoch (TPU-friendly, and
why the paper's padded cost model maps 1:1 onto this engine).

Weights can be served quantized: ``quant_bits`` picks the DEFAULT
precision, and a per-call ``generate(..., quant_bits=...)`` override lets
the scheduler serve each epoch at the method it decided.  Each requested
bit-width is quantized once from the full-precision weights and kept in a
small multi-precision cache (``params_for``), so swapping precision per
epoch costs a dict lookup — dense matmuls execute in the Pallas
dequant-matmul kernel (transformer family; other families dequantize at
load, see DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.api import Model, build_model
from repro.quant.ptq import dequantize_tree, quantize_tree


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_max) generated ids (post-prompt)
    lengths: np.ndarray         # (B,) emitted length per request
    batch: int


class ServingEngine:
    """Fixed-shape batched prefill + fused-decode executor for one model."""

    def __init__(self, cfg: ModelConfig, params: Any = None,
                 batch_capacity: int = 8, s_max: int = 512,
                 n_max: int = 128, quant_bits: int = 0,
                 eos_id: int = 0, seed: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.batch_capacity = batch_capacity
        self.s_max = s_max
        self.n_max = n_max
        self.eos_id = eos_id
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self._raw_params = params            # full precision master copy
        self._params_cache: dict = {}        # weight_bits -> param tree
        self.default_bits = self._canon_bits(quant_bits)
        self.params = self.params_for(quant_bits)
        self.precisions_served: set = set()  # bit-widths generate() ran at
        self.cache_len = s_max + n_max
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)
        # the fused decode loop consumes the prefill cache in place; CPU
        # does not implement donation (it would only warn), so gate it
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._decode_loop = jax.jit(self._decode_loop_fn,
                                    donate_argnums=donate)

    # -- multi-precision weight cache ---------------------------------------

    @staticmethod
    def _canon_bits(bits: Optional[int]) -> int:
        """0 and 16 both mean full precision (no quantized tree)."""
        return 0 if not bits or bits >= 16 else int(bits)

    def params_for(self, bits: Optional[int]):
        """Weights at ``bits`` precision, quantized once and cached so the
        scheduler can swap the served method every epoch."""
        bits = self._canon_bits(bits)
        if bits not in self._params_cache:
            if bits == 0:
                p = self._raw_params
            else:
                p = quantize_tree(self._raw_params, bits)
                if self.cfg.family not in ("dense", "moe", "vlm"):
                    # families whose matmuls don't route through common.mm
                    p = dequantize_tree(p)
            self._params_cache[bits] = p
        return self._params_cache[bits]

    # -- compiled step functions --------------------------------------------

    def _prefill_fn(self, params, batch):
        """Prompt pass; returns (first sampled token (B,), KV cache)."""
        logits, cache = self.model.prefill(params, batch, self.cache_len)
        cur = jnp.argmax(logits[..., :self.cfg.vocab], -1).astype(jnp.int32)
        return cur, cache

    def _decode_fn(self, params, cache, tokens, pos):
        return self.model.decode_step(params, cache, tokens, pos)

    def _decode_loop_fn(self, params, cache, cur, caps):
        """The entire autoregressive stage as ONE ``lax.while_loop``.

        Carries ``(cache, cur, out, lengths, done, t)`` on device; emits
        ``cur`` into ``out[:, t]`` for rows still alive (not done, under
        cap), flags EOS rows, steps the model, and exits as soon as no row
        can emit again.  Mirrors ``generate_reference`` bit for bit: dead
        rows keep stepping through the model (their cache writes are
        irrelevant — they never emit again), exactly like the legacy loop.
        """
        B = cur.shape[0]
        out0 = jnp.zeros((B, self.n_max), jnp.int32)
        lengths0 = jnp.zeros((B,), jnp.int32)
        done0 = jnp.zeros((B,), bool)

        def alive_mask(done, t):
            return (~done) & (t < caps)

        def cond(state):
            _, _, _, _, done, t = state
            return (t < self.n_max) & jnp.any(alive_mask(done, t))

        def body(state):
            cache, cur, out, lengths, done, t = state
            alive = alive_mask(done, t)
            out = out.at[:, t].set(jnp.where(alive, cur, out[:, t]))
            lengths = lengths + alive.astype(jnp.int32)
            done = done | ((cur == self.eos_id) & alive)
            logits, cache = self.model.decode_step(
                params, cache, cur[:, None], self.s_max + t)
            cur = jnp.argmax(logits[..., :self.cfg.vocab],
                             -1).astype(jnp.int32)
            return cache, cur, out, lengths, done, t + 1

        state = (cache, cur, out0, lengths0, done0, jnp.int32(0))
        _, _, out, lengths, _, _ = jax.lax.while_loop(cond, body, state)
        return out, lengths

    # -- public API ----------------------------------------------------------

    def synth_prompts(self, requests: Sequence, rng: np.random.Generator):
        """Synthesize random-token prompts + output caps for scheduled
        requests, clamped to this engine's static shapes (the cost-model
        lengths s_i/n_i may exceed a reduced engine's s_max/n_max)."""
        prompts = [rng.integers(1, self.cfg.vocab,
                                size=min(r.s, self.s_max)).tolist()
                   for r in requests]
        caps = [min(r.n, self.n_max) for r in requests]
        return prompts, caps

    def pad_prompts(self, prompts: Sequence[Sequence[int]]) -> np.ndarray:
        """Left-truncate/right-pad prompts to (batch_capacity, s_max)."""
        B = self.batch_capacity
        out = np.zeros((B, self.s_max), np.int32)
        for i, p in enumerate(prompts[:B]):
            p = list(p)[-self.s_max:]
            out[i, -len(p):] = p        # right-aligned => last slot is last
        return out

    def _prepare(self, prompts, n_tokens, quant_bits):
        """Shared generate() front half: resolve weights, pad the batch and
        ship (prompts, caps) to the device in ONE ``jax.device_put``."""
        bits = self.default_bits if quant_bits is None \
            else self._canon_bits(quant_bits)
        params = self.params_for(bits)
        self.precisions_served.add(bits)
        B = self.batch_capacity
        nb = len(prompts)
        assert nb <= B, (nb, B)
        caps = np.full((B,), self.n_max, np.int32)
        if n_tokens is not None:
            caps[:nb] = np.minimum(np.asarray(n_tokens, np.int32), self.n_max)
        caps[nb:] = 0

        tokens, caps_j = jax.device_put((self.pad_prompts(prompts), caps))
        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.vlm.n_img_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (B, self.cfg.encdec.n_audio_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return params, batch, caps_j, caps, nb

    def generate(self, prompts: Sequence[Sequence[int]],
                 n_tokens: Optional[Sequence[int]] = None,
                 greedy: bool = True,
                 quant_bits: Optional[int] = None) -> GenerationResult:
        """Prefill + fused device-resident decode of one batch.

        ``n_tokens`` caps each request's output; ``quant_bits`` serves this
        batch at an explicit weight precision (via the multi-precision
        cache), ``None`` uses the engine default.  Exactly one
        host→device and one device→host transfer happen per call — every
        token decision (sampling, EOS, caps) stays on device inside
        ``_decode_loop_fn``.
        """
        params, batch, caps_j, _, nb = self._prepare(prompts, n_tokens,
                                                     quant_bits)
        cur, cache = self._prefill(params, batch)
        out_j, lengths_j = self._decode_loop(params, cache, cur, caps_j)
        out, lengths = jax.device_get((out_j, lengths_j))
        return GenerationResult(tokens=out[:nb], lengths=lengths[:nb],
                                batch=nb)

    def generate_reference(self, prompts: Sequence[Sequence[int]],
                           n_tokens: Optional[Sequence[int]] = None,
                           greedy: bool = True,
                           quant_bits: Optional[int] = None
                           ) -> GenerationResult:
        """The legacy host-driven decode loop, kept as the interpret-style
        oracle: one blocking device→host transfer PER TOKEN.  The fused
        path must match it bit for bit (see tests/test_serving.py)."""
        params, batch, _, caps, nb = self._prepare(prompts, n_tokens,
                                                   quant_bits)
        B = self.batch_capacity
        cur_j, cache = self._prefill(params, batch)
        cur = np.asarray(jax.device_get(cur_j), np.int32)

        out = np.zeros((B, self.n_max), np.int32)
        lengths = np.zeros((B,), np.int32)
        done = np.zeros((B,), bool)

        for t in range(int(caps.max(initial=0))):
            alive = (~done) & (t < caps)
            if not alive.any():
                break
            out[alive, t] = cur[alive]
            lengths[alive] += 1
            done |= (cur == self.eos_id) & alive
            step_tok = jnp.asarray(cur)[:, None]
            pos = jnp.int32(self.s_max + t)
            logits, cache = self._decode(params, cache, step_tok, pos)
            cur = np.asarray(
                jax.device_get(
                    jnp.argmax(logits[..., :self.cfg.vocab], -1)), np.int32)
        return GenerationResult(tokens=out[:nb], lengths=lengths[:nb],
                                batch=nb)
