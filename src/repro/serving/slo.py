"""SLO machinery for the continuous loop (DESIGN.md §2.4).

Three pieces, all control-plane-side and executor-agnostic:

  * ``edf_order`` — the admission ordering: Earliest-Deadline-First
    WITHIN a priority class, higher classes first.  FIFO (arrival order)
    stays available through ``ContinuousRuntime(admission="fifo")`` for
    the A/B the SLO benchmark runs.
  * ``SpillRecord`` — the host-side progress record of a preempted
    request: what must survive eviction so the request can resume with
    its already-delivered prefix intact (the executor-specific payload),
    plus the attempt cap and boundary backoff that keep preemption from
    thrashing.
  * ``DegradationController`` — the graceful-degradation hysteresis:
    under sustained queue pressure or sagging SLO attainment the runtime
    enters degraded mode (cohorts start at the FASTEST admissible
    quantization method, lowest-priority queued work is shed), and exits
    only after the pressure clears for ``patience`` consecutive
    boundaries — enter/exit thresholds are separated so the controller
    cannot oscillate on a queue hovering at one threshold.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.request import Request


def edf_order(queue: Sequence[Request]) -> List[Request]:
    """Admission order: priority classes high→low, Earliest Deadline
    First within a class, arrival then rid as deterministic tiebreaks."""
    return sorted(queue, key=lambda r: (-r.priority, r.deadline,
                                        r.arrival, r.rid))


def pick_victim(residents: Sequence[Request],
                candidate: Request) -> Optional[Request]:
    """The resident row ``candidate`` may evict, or None.

    A candidate beats a victim iff it is of a STRICTLY higher priority
    class, or of the same class with a strictly earlier deadline — so
    preemption only ever trades a looser deadline for a tighter one and
    two equal requests can never evict each other (no livelock).  Among
    beatable residents the cheapest victim is chosen: lowest priority
    first, latest deadline second."""
    beatable = [v for v in residents
                if candidate.priority > v.priority
                or (candidate.priority == v.priority
                    and candidate.deadline < v.deadline)]
    if not beatable:
        return None
    return min(beatable, key=lambda v: (v.priority, -v.deadline, v.rid))


@dataclass
class SpillRecord:
    """Host-side survival record of a preempted request.

    ``payload`` is the executor's opaque resume token — the analytic
    executor spills ``{"remaining": tokens_left}``, the engine executor
    spills ``{"prompt": [...], "prefix": [...]}`` (the ORIGINAL prompt it
    must re-prefill plus the already-delivered tokens it must replay
    bit-exactly through the engine's forced-prefix mechanism).
    ``attempts`` caps how often the same request may be evicted
    (``ContinuousRuntime.max_preemptions``), and ``not_before`` is the
    global boundary index before which the spilled request is NOT
    re-admitted — a linear backoff (attempts × backoff_boundaries) that
    keeps a preempt/resume pair from ping-ponging every boundary."""
    request: Request
    payload: dict
    attempts: int = 1
    not_before: int = 0


@dataclass
class DegradationController:
    """Hysteresis controller for graceful degradation (DESIGN.md §2.4).

    ``observe`` is called once per segment boundary with the current
    queue depth and the SLO attainment over the last ``window`` finishes
    (None until anything finished).  Pressure = queue depth at or above
    ``queue_high``, or recent attainment below ``attain_floor``.  The
    controller flips to degraded only after ``patience`` CONSECUTIVE
    pressured boundaries, and recovers only after ``patience``
    consecutive boundaries with the queue back at or below ``queue_low``
    and attainment restored over at least ``min_samples`` DEGRADED-ERA
    finishes (the window is cleared on entry; an empty window is not
    recovery evidence) — the enter/exit thresholds are deliberately
    separated (queue_high > queue_low) so a queue hovering at one
    threshold cannot make the controller oscillate."""
    queue_high: int = 12          # enter pressure at/above this depth
    queue_low: int = 4            # exit pressure requires at/below this
    attain_floor: float = 0.9     # recent-attainment pressure threshold
    patience: int = 2             # consecutive boundaries before flipping
    window: int = 64              # finishes in the attainment window
    min_samples: int = 1          # degraded-era finishes required before
                                  # the exit streak may count — recovery
                                  # is judged on evidence, never on an
                                  # empty window
    shed_below_priority: int = 0  # degraded mode sheds queued work with
                                  # priority < this (0 = never shed)
    degraded: bool = False
    _enter_streak: int = field(default=0, repr=False)
    _exit_streak: int = field(default=0, repr=False)
    _recent: deque = field(default_factory=deque, repr=False)

    def record_finish(self, met_slo: bool) -> None:
        self._recent.append(bool(met_slo))
        while len(self._recent) > self.window:
            self._recent.popleft()

    @property
    def recent_attainment(self) -> Optional[float]:
        if not self._recent:
            return None
        return sum(self._recent) / len(self._recent)

    def observe(self, queue_len: int) -> bool:
        """Advance the hysteresis one boundary; returns the (possibly
        flipped) degraded flag."""
        att = self.recent_attainment
        pressured = queue_len >= self.queue_high \
            or (att is not None and att < self.attain_floor)
        relaxed = queue_len <= self.queue_low \
            and (att is None or att >= self.attain_floor)
        if self.degraded:
            # ``_recent`` was cleared on entry, so ``att is None`` here
            # means NOTHING finished in the degraded era — an empty
            # window is no evidence of recovery.  Exit requires at least
            # ``min_samples`` degraded-era finishes, all meeting the
            # attainment floor on average (the documented "judge
            # recovery on degraded-era finishes" contract).
            relaxed = relaxed and att is not None \
                and len(self._recent) >= max(1, self.min_samples)
        if not self.degraded:
            self._enter_streak = self._enter_streak + 1 if pressured else 0
            if self._enter_streak >= self.patience:
                self.degraded = True
                self._enter_streak = 0
                self._recent.clear()   # judge recovery on degraded-era
                                       # finishes, not the backlog's
        else:
            self._exit_streak = self._exit_streak + 1 if relaxed else 0
            if self._exit_streak >= self.patience:
                self.degraded = False
                self._exit_streak = 0
        return self.degraded

    def shed_candidates(self, queue: Sequence[Request]) -> List[Request]:
        """The queued requests degraded mode sheds: strictly below the
        configured priority floor — lowest-priority work goes first and
        work at/above the floor is never shed."""
        if not self.degraded or self.shed_below_priority <= 0:
            return []
        return [r for r in queue if r.priority < self.shed_below_priority]
