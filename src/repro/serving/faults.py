"""Deterministic fault injection for the serving data plane (§2.4).

A :class:`FaultPlan` is a SEEDED description of what goes wrong:
transient step exceptions, slow segments, and windows during which the
node's KV arena runs short of free pages.  :class:`FaultyExecutor`
wraps any executor (continuous ``step`` or epoch ``execute``) and
injects the plan; the runtimes answer with retry-with-backoff, a
watchdog around the step, cohort quarantine, and load shedding — all
with explicit accounting (``EpochMetrics.faults_injected`` /
``retried`` / ``watchdog_trips`` / ``quarantined`` / ``shed``).

The injection contract that makes fault runs TESTABLE: a transient
fault raises BEFORE the inner executor runs, so the wrapped step
mutates nothing — a retried step replays the exact same computation,
and a transient-only plan leaves every served token bit-identical to
the fault-free run (tests/test_slo_faults.py).  The wrapper draws from
its OWN rng, never the executor's, so the data plane's random stream
(synth prompts) is untouched by injection."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np


class TransientStepError(RuntimeError):
    """A transient data-plane failure (injected or real), raised BEFORE
    the step mutated any state — safe to retry.  ``mid`` attributes the
    failure to one hosted pool for quarantine accounting (``None`` is
    the single-model pool's key, not "unattributed")."""

    def __init__(self, message: str, mid: Optional[str] = None):
        super().__init__(message)
        self.mid = mid


@dataclass
class FaultPlan:
    """Seeded, deterministic fault schedule.

    ``p_transient`` — per-step probability of a :class:`TransientStepError`
    (capped at ``max_transient`` total).  ``p_slow``/``slow_s`` — per-step
    probability of an injected stall of ``slow_s`` wall seconds (trips
    the runtime watchdog when one is armed).  ``arena_holds`` — page
    squeeze windows ``(start_step, n_steps, n_pages)``: during the
    window up to ``n_pages`` of the node arena's free list are leased
    and held by the injector, so admission control sees a shrunken pool
    (and must defer, not crash); the pages are returned when the window
    closes.  The same (plan, seed) always injects the same schedule."""
    seed: int = 0
    p_transient: float = 0.0
    max_transient: Optional[int] = None
    p_slow: float = 0.0
    slow_s: float = 0.0
    arena_holds: tuple = ()        # ((start_step, n_steps, n_pages), ...)


class FaultyExecutor:
    """Transparent executor proxy that injects a :class:`FaultPlan`.

    Wraps a ``ContinuousExecutor`` (intercepting ``step``) or an epoch
    ``Executor`` (intercepting ``execute``); every other attribute —
    pools, admission gates, preemption, token collection — passes
    through to the wrapped executor untouched, so the runtimes drive a
    faulty executor exactly like a healthy one."""

    def __init__(self, inner, plan: FaultPlan):
        self._inner = inner
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self._step_no = 0
        self._held: dict = {}      # window index -> held page leases
        self.injected = 0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    # -- injection -----------------------------------------------------------

    def _squeeze_arena(self, i: int) -> None:
        arena = getattr(self._inner, "arena", None)
        if arena is None:
            return
        for w, (start, n_steps, n_pages) in enumerate(self.plan.arena_holds):
            if start <= i < start + n_steps and w not in self._held:
                take = min(int(n_pages), arena.free_pages)
                if take > 0:
                    self._held[w] = arena.alloc(take)
            elif i >= start + n_steps and w in self._held:
                arena.free(self._held.pop(w))

    def _maybe_inject(self, what: str) -> None:
        """One injection decision; raises on a transient fault.  Drawn
        from the wrapper's own rng — a retry of the SAME boundary draws
        the next schedule entry (so a retry can re-fault), and the inner
        executor's stream is never advanced by injection."""
        plan = self.plan
        if plan.p_slow > 0 and self._rng.uniform() < plan.p_slow:
            time.sleep(plan.slow_s)
        if plan.p_transient > 0 \
                and (plan.max_transient is None
                     or self.injected < plan.max_transient) \
                and self._rng.uniform() < plan.p_transient:
            self.injected += 1
            pools = getattr(self._inner, "pool_ids", lambda: [None])()
            mid = pools[int(self._rng.integers(len(pools)))] if pools \
                else None
            raise TransientStepError(
                f"injected transient fault ({what} #{self._step_no})",
                mid=mid)

    # -- intercepted entry points -------------------------------------------

    def step(self, env, k):
        i = self._step_no
        self._step_no += 1
        self._squeeze_arena(i)
        self._maybe_inject("step")
        return self._inner.step(env, k)

    def execute(self, env, decision):
        self._step_no += 1
        self._maybe_inject("execute")
        return self._inner.execute(env, decision)
