"""EpochRuntime: THE epoch/queue lifecycle loop (paper Fig. 2 + §IV).

Historically the protocol — arrivals join at the epoch boundary, queued
requests age, hopeless requests drop, a scheduler picks a batch, served
requests leave — was hand-rolled three times (analytic sim, real-engine
serving, multi-LLM benchmarks) with drifting semantics.  It now lives
here exactly once, parameterized on two axes:

  * control plane — a ``SchedulerPolicy`` (core/policy.py): what to batch,
    WITH WHICH QUANTIZATION METHOD (``Decision.quants``), and the
    feasibility oracle the runtime re-checks it against;
  * data plane — an ``Executor``: how a decision is carried out.
    ``AnalyticExecutor`` charges cost-model time only (the paper's
    figures); ``EngineExecutor`` runs each batch on real JAX models via
    ``ServingEngine.generate`` — at the decision's precision, through the
    engine's multi-precision weight cache — clamping to engine capacity
    with a feasibility re-check and spill accounting instead of the old
    silent truncation.

The epoch loop records each epoch's decided method per model in its
``EpochTrace.quants`` and aggregates ``EpochMetrics.served_by_method``,
so adaptive-precision runs are auditable epoch by epoch.  It also times
every ``executor.execute`` call (``EpochTrace.wall_s``, aggregated into
``EpochMetrics.wall_s`` / ``tokens_per_s``) — under ``EngineExecutor``
that is the real data plane's measured decode throughput, since
``ServingEngine.generate`` blocks on its single device→host transfer.  (The historical
``simulate`` / ``serve_epochs`` / ``sweep`` shims are gone; drive this
class directly.)

``ContinuousRuntime`` is the iteration-level sibling: the same queue
lifecycle, but the data plane (a ``ContinuousExecutor``) runs chunked
decode segments and ADMITS queued requests at every segment boundary —
each slot refill gated by ``policy.validate()`` on the joint
resident-plus-candidate batch, so the paper's P1 constraints still hold
for everything on the device.  On a ``MultiLLMEnv`` the executor keeps
one device-resident cohort PER HOSTED ENGINE and every admission is
additionally re-checked against the authoritative joint oracle
(``multi.multi_feasible``) — per-model feasibility does not compose on
shared node budgets, and a policy that pretends it does raises
``InfeasibleDecisionError`` instead of serving.  Each freshly started
cohort picks its quantization method through the policy's
``select_quant`` (the PR-2 ``quant=auto`` descent on the continuous
path), served via the engine's multi-precision weight cache and
recorded in ``EpochTrace.quants``.  See DESIGN.md §2.1/§2.2.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.environment import EdgeEnv
from repro.core.metrics import EpochMetrics, EpochTrace
from repro.core.multi import MultiLLMEnv, multi_feasible
from repro.core.policy import (Decision, DrainStallError,
                               InfeasibleDecisionError,
                               SchedulerPolicy, as_policy)
from repro.core.quantization import QuantMethod, candidate_methods
from repro.core.request import Request, RequestGenerator
from repro.serving.faults import TransientStepError
from repro.serving.slo import (DegradationController, SpillRecord,
                               edf_order, pick_victim)

Env = Union[EdgeEnv, MultiLLMEnv]


def still_viable(env: EdgeEnv, r: Request, now: float) -> bool:
    """Could this queued request still meet its deadline if scheduled at the
    *next* epoch boundary?  Lower bound: comm slots + its lone compute at
    its true prompt length (<= any batched/padded execution).

    The bound is computed under the env's deployed method even when a
    policy selects quant per epoch — it is a drop heuristic, and keeping
    it method-independent keeps fixed- and adaptive-method runs on the
    same queue trajectory for like-for-like comparison."""
    t_w = now - r.arrival
    cm = env.cost_model()
    lone = env.quant.beta * (cm.prefill_flops(r.s, 1)
                             + cm.decode_flops(r.s, [r.n])) / env.C
    return t_w + env.T_U + lone + env.T_D <= r.tau + 1e-12


# ---------------------------------------------------------------------------
# Executors: the data plane behind a scheduling decision
# ---------------------------------------------------------------------------


class Executor:
    """How a scheduling decision is carried out each epoch."""

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        """Clamp a decision to this data plane's capacity.  Returns the
        (possibly reduced) decision plus the spilled requests, which stay
        in the queue for later epochs."""
        return decision, []

    def execute(self, env: Env, decision: Decision) -> int:
        """Run the decision; returns the number of generated tokens."""
        raise NotImplementedError


class AnalyticExecutor(Executor):
    """Cost-model-time execution: nothing runs, latency/memory are charged
    analytically (P1's constraints).  The paper's evaluation path."""

    def execute(self, env: Env, decision: Decision) -> int:
        return 0


class EngineExecutor(Executor):
    """Real data plane: each batch executes on a ``ServingEngine``
    (batched prefill + decode on the JAX model).

    ``engines`` is one engine (single-model node) or a dict keyed by
    ``model_id`` mirroring a MultiLLMEnv's hosted deployments.  Batches
    larger than an engine's static ``batch_capacity`` are clamped and the
    spill is reported to the runtime (re-queued + counted) — the clamped
    batch is re-validated against the policy's own oracle rather than
    trusted silently.

    When a decision carries a quant assignment, each batch executes at
    that method's weight precision via the engine's multi-precision
    weight cache (``ServingEngine.params_for``) — the decided precision
    actually reaches the Pallas dequant-matmul kernel.
    """

    def __init__(self, engines, rng: Optional[np.random.Generator] = None,
                 seed: int = 0):
        if not isinstance(engines, dict):
            engines = {None: engines}
        self.engines = engines
        self.rng = rng if rng is not None else np.random.default_rng(seed)

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        spilled: List[Request] = []
        batches = {}
        for mid, batch in decision.batches.items():
            cap = self.engines[mid].batch_capacity
            batches[mid] = batch[:cap]
            spilled.extend(batch[cap:])
        if not spilled:
            return decision, []
        # A split decision's sub-batch structure must survive the clamp:
        # the flat batch is the concatenation of the sub-batches, so a
        # prefix cut truncates from the LAST sub-batch backwards — kept
        # rows stay in their decided-method group (an entry collapsing
        # to one sub-batch drops back to the flat form; its method is
        # already ``quants[mid]``, the primary).
        splits = {}
        for mid, subs in decision.splits.items():
            kept = {r.rid for r in batches.get(mid, [])}
            subs2 = [([r for r in b if r.rid in kept], q)
                     for b, q in subs]
            subs2 = [(b, q) for b, q in subs2 if b]
            if len(subs2) > 1:
                splits[mid] = subs2
        clamped = Decision(batches=batches, stats=decision.stats,
                           quants=decision.quants, splits=splits)
        # Feasibility is monotone under request removal for every shipped
        # policy, but the oracle is the contract — re-check, don't assume.
        if not policy.validate(env, clamped):
            raise InfeasibleDecisionError(
                f"{policy.spec}: capacity-clamped batch failed its own "
                f"oracle")
        return clamped, spilled

    def execute(self, env: Env, decision: Decision) -> int:
        tokens = 0
        for mid, batch in decision.batches.items():
            if not batch:
                continue
            engine = self.engines[mid]
            subs = decision.splits.get(mid)
            if subs:
                # split epoch (DESIGN.md §1.1): each sub-batch executes
                # back to back at its OWN method — the engine's
                # multi-precision weight cache makes the inter-sub swap
                # a dict lookup (its latency is charged by the control
                # plane's swap-cost term, not re-measured here)
                for sub, q in subs:
                    if not sub:
                        continue
                    prompts, caps = engine.synth_prompts(sub, self.rng)
                    result = engine.generate(
                        prompts, caps,
                        quant_bits=None if q is None else q.serve_bits)
                    tokens += int(result.lengths.sum())
                continue
            prompts, caps = engine.synth_prompts(batch, self.rng)
            q = decision.quants.get(mid)
            result = engine.generate(
                prompts, caps,
                quant_bits=None if q is None else q.serve_bits)
            tokens += int(result.lengths.sum())
        return tokens


# ---------------------------------------------------------------------------
# The one control loop
# ---------------------------------------------------------------------------


class EpochRuntime:
    """Drives the epoch protocol for any (env, policy, executor) triple."""

    def __init__(self, env: Env, policy: Union[str, SchedulerPolicy],
                 executor: Optional[Executor] = None):
        self.env = env
        self.policy = as_policy(policy)
        self.executor = executor or AnalyticExecutor()

    @property
    def T_E(self) -> float:
        return self.env.T_E

    def _env_for(self, r: Request) -> Optional[EdgeEnv]:
        """The single-model constraint view serving this request."""
        if isinstance(self.env, MultiLLMEnv):
            return self.env.env_for(r)
        return self.env

    @staticmethod
    def _resolve_gen(rate: Optional[float], seed: int,
                     gen: Optional[RequestGenerator]) -> RequestGenerator:
        """The ONE default workload (paper §IV marginals) — shared by the
        epoch and continuous loops so their traffic stays comparable."""
        if gen is not None:
            return gen
        if rate is None:
            raise ValueError("provide either rate= or gen=")
        return RequestGenerator(rate=rate, seed=seed,
                                lengths=(128, 256, 512))

    def _age_and_drop(self, queue: List[Request], now: float
                      ) -> Tuple[List[Request], int]:
        """Age every queued request to ``now`` and drop the hopeless (or
        untargeted) ones — the ONE copy of the viability bookkeeping,
        shared by the epoch and continuous loops so their queue
        trajectories cannot drift."""
        viable: List[Request] = []
        dropped = 0
        for r in queue:
            r.t_w = now - r.arrival
            env_r = self._env_for(r)
            if env_r is not None and still_viable(env_r, r, now):
                viable.append(r)
            else:
                dropped += 1
        return viable, dropped

    def run(self, rate: Optional[float] = None, n_epochs: int = 30,
            seed: int = 0, gen: Optional[RequestGenerator] = None,
            warmup_epochs: int = 1,
            tag_arrivals: Optional[Callable[[List[Request]],
                                            List[Request]]] = None
            ) -> EpochMetrics:
        """Run the epoch protocol with Poisson(rate) arrivals.

        The first ``warmup_epochs`` epochs run but are excluded from the
        aggregate metrics (queue fill-up transient).  ``tag_arrivals``
        lets multi-LLM workloads assign each arrival a ``model_id``.
        """
        gen = self._resolve_gen(rate, seed, gen)
        T_E = self.T_E
        m = EpochMetrics(n_epochs=n_epochs, T_E=T_E)
        queue: List[Request] = []

        for e in range(n_epochs + warmup_epochs):
            t0 = e * T_E
            counting = e >= warmup_epochs
            # requests that arrived during the previous epoch join the queue
            arrivals = gen.within(t0 - T_E, t0) if e else []
            if tag_arrivals is not None:
                arrivals = tag_arrivals(arrivals)
            if counting:
                m.arrived += len(arrivals)
            queue.extend(arrivals)

            # age the queue; drop hopeless (or untargeted) requests
            queue, n_dropped = self._age_and_drop(queue, t0)
            if counting:
                m.dropped += n_dropped

            decision = self.policy.schedule(self.env, queue)
            decision, spilled = self.executor.admit(self.env, self.policy,
                                                    decision)
            # authoritative re-check against the policy's own oracle
            # (schedulers must not cheat)
            if not self.policy.validate(self.env, decision):
                raise InfeasibleDecisionError(
                    f"{self.policy.spec} returned an infeasible batch")
            # real executors block on the result (ServingEngine.generate
            # device_gets), so this wall-clock is the data plane's t_A+t_I
            t_exec = time.perf_counter()
            tokens, n_faults = 0, 0
            for attempt in range(4):
                # bounded retry: a TransientStepError is raised BEFORE
                # the data plane mutated anything (serving/faults.py),
                # so replaying the epoch's execute is safe; after the
                # retry budget the epoch proceeds unexecuted (analytic
                # charging is unaffected; the fault is accounted).
                try:
                    tokens = self.executor.execute(self.env, decision)
                    break
                except TransientStepError:
                    n_faults += 1
                    if counting:
                        m.faults_injected += 1
                        if attempt < 3:
                            m.retried += 1
            wall_s = time.perf_counter() - t_exec

            sel = decision.selected
            # the method each served model actually ran with this epoch
            quants = {mid: decision.quant_for(mid, self.env).name
                      for mid, batch in decision.batches.items() if batch}
            if counting:
                m.served += len(sel)
                m.batch_sizes.append(len(sel))
                m.nodes_visited += decision.stats.nodes_visited
                m.leaves_checked += decision.stats.leaves_checked
                m.truncated += len(spilled)
                m.generated_tokens += tokens
                m.wall_s += wall_s
                for mid, batch in decision.batches.items():
                    if batch:
                        # per sub-batch: a split epoch serves one model
                        # at MORE than one precision (identical to the
                        # flat accounting for non-split decisions)
                        for sub, q in decision.sub_batches(mid, self.env):
                            m.served_by_method[q.name] = \
                                m.served_by_method.get(q.name, 0) + len(sub)
                        m.served_by_model[mid] = \
                            m.served_by_model.get(mid, 0) + len(batch)
            m.traces.append(EpochTrace(
                epoch=e, arrived=len(arrivals), dropped=n_dropped,
                selected_rids=[r.rid for r in sel], truncated=len(spilled),
                nodes_visited=decision.stats.nodes_visited,
                generated_tokens=tokens, counted=counting,
                quants=quants, wall_s=wall_s, faults=n_faults))

            chosen = {r.rid for r in sel}
            queue = [r for r in queue if r.rid not in chosen]
        m.final_queue_rids = [r.rid for r in queue]
        return m


# ---------------------------------------------------------------------------
# Continuous batching: chunked decode segments + mid-epoch admission
# ---------------------------------------------------------------------------


class ContinuousExecutor:
    """Slot-structured data plane behind ``ContinuousRuntime``.

    One POOL of ``capacity`` request slots per hosted model.  Resident
    requests advance ``k`` tokens per ``step`` (one chunked decode
    segment); rows that finish free their slot, and freed slots are
    refillable between segments — the iteration-level batching the
    epoch protocol cannot express.  Subclasses implement the token
    mechanics; this base owns the slot bookkeeping shared by both.
    """

    #: whether ``requant`` changes what the data plane actually SERVES
    #: (precision/speed), not just the bookkeeping.  The analytic plane
    #: emits k tokens per segment regardless of method, so flipping a
    #: live cohort there cannot deliver the loosened admission bound the
    #: oracle would price — the runtime's rising-edge requant skips
    #: planes where the flip is serving-inert.
    requant_effective = False

    def __init__(self):
        self._pools: Dict[Optional[str], dict] = {}
        # rid -> the QuantMethod the request was DECIDED at when placed
        # (split serving, DESIGN.md §1.1): per-row accounting and the
        # engine executor's sub-batch grouping follow this, not just the
        # pool-level cohort method
        self._rid_method: Dict[int, QuantMethod] = {}

    # -- pool construction ---------------------------------------------------

    def bind(self, env: Env) -> None:
        """(Re)build one empty pool per hosted model of ``env``."""
        mids = list(env.envs) if isinstance(env, MultiLLMEnv) else [None]
        self._pools = {mid: self._make_pool(mid) for mid in mids}

    def _make_pool(self, mid: Optional[str]) -> dict:
        return {"capacity": self._capacity(mid), "resident": {},
                "pending": [], "quant": None}

    def _capacity(self, mid: Optional[str]) -> int:
        raise NotImplementedError

    # -- slot bookkeeping (shared) -------------------------------------------

    def pool_ids(self) -> List[Optional[str]]:
        return list(self._pools)

    def resident(self, mid: Optional[str]) -> List[Request]:
        """Requests currently occupying slots (incl. pending refills) —
        the batch an admission candidate must stay jointly feasible
        with."""
        pool = self._pools[mid]
        return list(pool["resident"].values()) \
            + [r for _, r, _, _ in pool["pending"]]

    def free_slots(self, mid: Optional[str]) -> int:
        pool = self._pools[mid]
        return pool["capacity"] - len(pool["resident"]) \
            - len(pool["pending"])

    def accepts(self, mid: Optional[str], r: Request) -> bool:
        """Slot-structure gate only (P1 feasibility is the runtime's
        job, via ``policy.validate``)."""
        return mid in self._pools and self.free_slots(mid) > 0

    def place(self, mid: Optional[str], r: Request,
              resume: Optional[dict] = None,
              quant: Optional[QuantMethod] = None) -> None:
        """Claim the lowest free slot for an admitted request; the refill
        executes at the start of the next ``step`` (engines batch all of
        a boundary's admissions into ONE prefill).  ``resume`` is the
        opaque payload a prior ``preempt`` of this request returned —
        the subclass restores the spilled progress when the refill
        lands.  ``quant`` is the method THIS request was decided at
        (split serving): ``None`` means method-agnostic — the request
        joins whatever the pool's cohort serves at — while a tagged
        request only joins a matching-precision cohort (the engine
        executor holds it until that sub-batch starts)."""
        pool = self._pools[mid]
        taken = set(pool["resident"]) \
            | {s for s, _, _, _ in pool["pending"]}
        slot = min(s for s in range(pool["capacity"]) if s not in taken)
        pool["pending"].append((slot, r, resume, quant))
        if quant is not None:
            self._rid_method[r.rid] = quant

    def evictable(self, mid: Optional[str]) -> List[Request]:
        """Rows preemption may evict: resident ON the data plane.
        Pending refills are excluded — they were admitted this very
        boundary and have not prefilled yet, so evicting them would
        churn admissions without freeing any device state."""
        return list(self._pools[mid]["resident"].values())

    def preempt(self, mid: Optional[str], rid: int) -> dict:
        """Evict the RESIDENT request ``rid`` from its slot at a segment
        boundary, returning the opaque resume payload a later
        ``place(..., resume=)`` restores (DESIGN.md §2.4).  Slot and any
        physical KV are released immediately; the runtime owns the
        re-queue/backoff/attempt bookkeeping."""
        raise NotImplementedError

    def evacuate(self, mid: Optional[str]) -> List[Request]:
        """Empty pool ``mid`` entirely — resident AND pending — and
        return the removed requests.  Quarantine support: the runtime
        sheds (or re-queues) the returned work with explicit accounting;
        the pool is left clean so a later un-quarantine could reuse
        it."""
        raise NotImplementedError

    def idle(self) -> bool:
        return all(not p["resident"] and not p["pending"]
                   for p in self._pools.values())

    def block_usage(self) -> Tuple[int, int, int, int]:
        """KV-block accounting snapshot, recorded by the runtime after
        every segment: ``(blocks_in_use, blocks_total, live_tokens,
        alloc_tokens)``.  Data planes without a physical block pool
        (analytic, slab engines) report slot-level occupancy — one
        "block" per resident request against the node's slot capacity,
        with no token accounting (0, 0).  The arena-backed engine
        executor overrides this with true page counts, and
        ``alloc_tokens - live_tokens`` is the allocated-but-dead volume
        behind ``EpochMetrics.fragmentation``."""
        occupied = sum(len(p["resident"]) for p in self._pools.values())
        capacity = sum(p["capacity"] for p in self._pools.values())
        return occupied, capacity, 0, 0

    def topup_pages(self) -> int:
        """Cumulative pages leased via segment-boundary top-ups
        (DESIGN.md §2.3) — 0 for data planes without incremental
        leasing.  The runtime records the per-run delta as
        ``EpochMetrics.kv_topup_pages``."""
        return 0

    # -- per-cohort quantization lifecycle -----------------------------------

    def set_quant(self, mid: Optional[str],
                  method: Optional[QuantMethod]) -> None:
        """Record the method the cohort STARTING in pool ``mid`` is served
        with (``None`` = the deployment default).  Called by the runtime
        at the first admission into an empty pool; the value sticks for
        the cohort's whole life (refills join at the cohort's precision)
        and is overwritten when the next cohort starts."""
        self._pools[mid]["quant"] = method

    def quant_of(self, mid: Optional[str]) -> Optional[QuantMethod]:
        """The method the pool's current cohort is served with (None =
        deployment default)."""
        return self._pools[mid]["quant"]

    def decided_quant(self, rid: int,
                      default: Optional[QuantMethod] = None
                      ) -> Optional[QuantMethod]:
        """The method request ``rid`` was decided at when placed (split
        serving), else ``default`` — the runtime rebuilds per-model
        sub-batch structure for its trial Decisions from this."""
        return self._rid_method.get(rid, default)

    def requant(self, mid: Optional[str],
                method: Optional[QuantMethod]) -> None:
        """Re-point pool ``mid``'s LIVE cohort at ``method`` mid-flight
        (graceful degradation, DESIGN.md §2.4): the pool's method flips
        and resident rows + pending refills are re-tagged so accounting
        (``method_name``) and sub-batch grouping follow.  Subclasses
        additionally swap the data plane's served precision."""
        pool = self._pools[mid]
        pool["quant"] = method
        for r in pool["resident"].values():
            self._rid_method[r.rid] = method
        pool["pending"] = [(s, r, res, method)
                           for s, r, res, _ in pool["pending"]]
        for _, r, _, _ in pool["pending"]:
            self._rid_method[r.rid] = method

    def arena_blocked(self, mid: Optional[str], r: Request) -> bool:
        """True when admitting ``r`` into ``mid`` is refused by the
        node's PHYSICAL KV budget (the paged arena) even though the pool
        has free slots — the case where preemption must look at OTHER
        pools' residents, since any cohort's released pages free the
        shared arena.  Data planes without a page pool are never
        arena-blocked."""
        return False

    def method_name(self, mid: Optional[str], env_r: EdgeEnv,
                    rid: Optional[int] = None) -> str:
        """Label for ``served_by_method`` accounting: the precision this
        request actually served at — its OWN decided method when it was
        placed with one (split cohorts serve rows at different methods),
        else the pool's cohort method, else the env's deployed method
        (engine subclasses may add engine-level overrides)."""
        q = self._rid_method.get(rid) if rid is not None else None
        if q is None:
            q = self._pools[mid]["quant"]
        return q.name if q is not None else env_r.quant.name

    # -- token mechanics (subclass contract) ---------------------------------

    def tokens_per_epoch(self) -> int:
        """Decode steps one epoch is provisioned for (sets the default
        segment grid: ``segments_per_epoch = ceil(tokens_per_epoch/k)``,
        so chunk size k = tokens_per_epoch reduces to one admission point
        per epoch — the epoch protocol's grid)."""
        raise NotImplementedError

    def step(self, env: Env, k: int
             ) -> Tuple[List[Tuple[Optional[str], Request, int]], float]:
        """Apply pending refills, advance every pool by at most ``k``
        tokens, and return (finished rows as ``(model_id, request,
        generated_tokens)``, mean occupied-slot fraction during the
        segment)."""
        raise NotImplementedError


class AnalyticContinuousExecutor(ContinuousExecutor):
    """Cost-model-time continuous data plane: nothing runs, resident
    requests emit ``k`` tokens per segment and finish after ``n_i`` —
    the deterministic vehicle for the conservation property tests (like
    ``AnalyticExecutor``, it reports 0 generated tokens)."""

    def __init__(self, capacity: Union[int, Dict[Optional[str], int]] = 8,
                 tokens_per_epoch_: int = 512):
        super().__init__()
        self._cap = capacity
        self._tokens_per_epoch = tokens_per_epoch_

    def _make_pool(self, mid):
        pool = super()._make_pool(mid)
        pool["remaining"] = {}          # slot -> output tokens left
        return pool

    def _capacity(self, mid: Optional[str]) -> int:
        return self._cap[mid] if isinstance(self._cap, dict) else self._cap

    def tokens_per_epoch(self) -> int:
        return self._tokens_per_epoch

    def step(self, env, k):
        finished, occupied, capacity = [], 0, 0
        for mid, pool in self._pools.items():
            for slot, r, resume, _ in pool["pending"]:
                pool["resident"][slot] = r
                # a resumed request keeps its spilled progress: only the
                # tokens it had NOT yet emitted remain to be served
                pool["remaining"][slot] = resume["remaining"] \
                    if resume is not None else r.n
            pool["pending"].clear()
            occupied += len(pool["resident"])
            capacity += pool["capacity"]
            for slot, r in list(pool["resident"].items()):
                pool["remaining"][slot] -= k
                if pool["remaining"][slot] <= 0:
                    finished.append((mid, r, 0))
                    del pool["resident"][slot]
                    del pool["remaining"][slot]
        return finished, occupied / capacity if capacity else 0.0

    def preempt(self, mid, rid):
        pool = self._pools[mid]
        slot = next(s for s, r in pool["resident"].items() if r.rid == rid)
        del pool["resident"][slot]
        return {"remaining": pool["remaining"].pop(slot)}

    def evacuate(self, mid):
        pool = self._pools[mid]
        removed = list(pool["resident"].values()) \
            + [r for _, r, _, _ in pool["pending"]]
        pool["resident"].clear()
        pool["remaining"].clear()
        pool["pending"].clear()
        return removed


class EngineContinuousExecutor(ContinuousExecutor):
    """Real continuous data plane: each pool is a ``ServingEngine``
    COHORT driven through the chunked decode API.

    Admissions buffered by ``place`` become ONE prefill at the next
    ``step`` — ``start_chunked`` for an empty pool, ``refill_chunked``
    spliced into the live cohort otherwise.  Each segment is one jitted
    ``generate_chunked`` call plus one small ``poll_chunked`` readback
    (the per-segment host sync that buys the admission point).  A row
    finishes when EOS fires or its cap fills; when a cohort drains (or
    its shared cache position exhausts at ``n_max``) the pool resets and
    the next admission starts a fresh cohort.  ``accepts`` additionally
    requires the cohort headroom to cover a candidate's full clamped
    service ``min(n_i, n_max)`` so refills are never silently truncated.

    ``engines`` is one engine or a ``{model_id: ServingEngine}`` dict
    keyed like the hosted ``MultiLLMEnv`` (mirroring ``EngineExecutor``)
    — ONE device-resident cohort per hosted engine, all advancing on the
    node's shared segment grid.  Refill caps are clamped to the target
    cohort's OWN remaining headroom (``node_headroom``); cross-cohort
    memory pressure is expressed through the paged KV ``arena`` when one
    is attached — each admission must reserve its cap-aware pages (its
    own ``t + n`` span, not a worst-case slab stripe) from
    the node-wide pool, and pages released by ANY cohort's completed
    rows are immediately allocatable by every other (the historical
    min-headroom clamp that let one long-running cohort throttle every
    model's admission is gone; DESIGN.md §2.3).

    Each cohort's served precision is the runtime-decided method
    (``set_quant``, from ``policy.select_quant`` at cohort start) via
    the engine's multi-precision weight cache; ``quant_bits`` optionally
    pins an engine-level fallback for cohorts with no decided method —
    an override, not a scheduled method, so ``served_by_method`` records
    it as ``"weight_bits=<b>"`` rather than borrowing a METHODS name
    whose beta/accuracy terms were never applied.
    """

    # a mid-flight requant re-points the live DecodeState at another
    # entry of the multi-precision weight cache: the very next segment
    # really does serve at the new precision
    requant_effective = True

    def __init__(self, engines, rng: Optional[np.random.Generator] = None,
                 seed: int = 0, quant_bits: Optional[int] = None,
                 collect_tokens: bool = False, arena=None):
        super().__init__()
        if not isinstance(engines, dict):
            engines = {None: engines}
        self.engines = engines
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.quant_bits = quant_bits
        # node-wide paged KV arena (serving/kv_arena.py): pools whose
        # engine can serve paged run arena-backed cohorts, admission
        # gated by page reservation instead of the min-headroom clamp
        self.arena = arena
        self._pending_pages = 0
        # rid -> generated token ids, filled at completion when enabled
        # (one full poll per segment instead of the light occupancy poll
        # — equivalence tests only; leave off on the hot path)
        self.collect_tokens = collect_tokens
        self.outputs: Dict[int, np.ndarray] = {}

    def _make_pool(self, mid):
        if mid not in self.engines:
            raise KeyError(
                f"no ServingEngine bound for hosted model {mid!r}; "
                f"executor hosts {sorted(map(str, self.engines))}")
        pool = super()._make_pool(mid)
        eng = self.engines[mid]
        paged = self.arena is not None and eng.paged_capable \
            and eng.cache_len % self.arena.block_tokens == 0
        # prompts: slot -> synthesized prompt of the resident row.  Kept
        # because preemption resume must re-prefill the IDENTICAL prompt
        # (synthesis is rng-driven and unrepeatable) — dropped again the
        # moment the row finishes.
        pool.update(engine=eng, state=None, t=0, paged=paged, prompts={})
        return pool

    def _capacity(self, mid) -> int:
        return self.engines[mid].batch_capacity

    def tokens_per_epoch(self) -> int:
        return max(e.n_max for e in self.engines.values())

    def method_name(self, mid, env_r: EdgeEnv,
                    rid: Optional[int] = None) -> str:
        q = self._rid_method.get(rid) if rid is not None else None
        if q is None:
            q = self._pools[mid]["quant"]
        if q is not None:
            return q.name
        if self.quant_bits is None:
            return env_r.quant.name
        return f"weight_bits={self.quant_bits}"

    def _cohort_bits(self, pool):
        """Precision spec a starting cohort is served at: the decided
        method's ``serve_bits`` (an int, or a (w, a) pair for W8A8 —
        routed to the engine's int8-activation tier), else the
        engine-level override, else None (the engine default)."""
        q = pool["quant"]
        return q.serve_bits if q is not None else self.quant_bits

    def node_headroom(self, mid) -> int:
        """Output tokens a refill into ``mid`` can be promised: the
        target pool's OWN cohort headroom (``n_max`` for a fresh
        cohort).  Historically this was clamped to the MINIMUM headroom
        across every live cohort on the node — a blunt provisioning
        proxy under which one long-running cohort throttled every
        model's admission.  The paged arena replaced that proxy with
        true per-block accounting: cross-cohort memory pressure is now
        expressed as page reservations (``accepts`` asks the arena
        whether the candidate's worst-case pages fit), and the paper's
        joint constraints stay with the authoritative ``multi_feasible``
        oracle at admission — so another cohort's AGE no longer caps
        this cohort's refill promises (DESIGN.md §2.3)."""
        pool = self._pools[mid]
        eng = self.engines[mid]
        return eng.n_max if pool["state"] is None \
            else eng.headroom(pool["t"])

    def _pages_needed(self, mid, r) -> int:
        """Cap-aware arena pages admitting ``r`` into ``mid`` reserves
        at the next boundary (0 for slab pools): the pages the row will
        lease over its WHOLE life given its own cap ``min(n, n_max)`` at
        the pool's current cohort step — initial prompt+first-write
        lease plus every future segment-boundary top-up — not the
        historical worst-case span to the end of the cache."""
        pool = self._pools[mid]
        if not pool.get("paged"):
            return 0
        eng = pool["engine"]
        t = 0 if pool["state"] is None else pool["t"]
        return eng.pages_for_admission(t, min(int(r.n), eng.n_max),
                                       self.arena.block_tokens)

    def _outstanding_pages(self) -> int:
        """Pages live paged cohorts are still entitled to lease via
        future top-ups (Σ ``lease_last - lease_end`` over resident
        rows).  Charged against admission BEFORE this boundary's refills
        land, so incremental top-ups can never race a fresh admission
        into :class:`ArenaExhausted`."""
        total = 0
        for pool in self._pools.values():
            if pool.get("paged") and pool["state"] is not None:
                total += pool["engine"].lease_commitment(pool["state"])
        return total

    def accepts(self, mid, r) -> bool:
        if not super().accepts(mid, r):
            return False
        pool = self._pools[mid]
        if pool.get("paged"):
            # per-block admission: can this request's cap-aware pages be
            # reserved, on top of boundary admissions already pending
            # AND the top-up entitlement resident rows still hold?  (The
            # multi_feasible oracle stays authoritative for the paper's
            # constraints — this gates physical KV.)
            need = self._pages_needed(mid, r)
            budget = self.arena.free_pages - self._pending_pages \
                - self._outstanding_pages()
            if budget < need:
                return False
        if pool["state"] is None:
            return True     # fresh cohort: full n_max headroom of its own
        return self.node_headroom(mid) >= min(r.n, pool["engine"].n_max)

    def arena_blocked(self, mid, r) -> bool:
        """``accepts`` refused ``r`` on the shared PAGE budget while the
        pool itself had room (free slot + headroom): the signal that
        cross-pool preemption can help — evicting any cohort's resident
        returns its pages to the node arena (DESIGN.md §2.3/§2.4)."""
        pool = self._pools[mid]
        if not pool.get("paged") or self.free_slots(mid) <= 0:
            return False
        if pool["state"] is not None and \
                self.node_headroom(mid) < min(r.n, pool["engine"].n_max):
            return False    # headroom-bound, not memory-bound
        need = self._pages_needed(mid, r)
        budget = self.arena.free_pages - self._pending_pages \
            - self._outstanding_pages()
        return budget < need

    def place(self, mid, r, resume=None, quant=None):
        # reserve the candidate's cap-aware pages against this boundary
        # so a burst of same-boundary admissions can't jointly overdraw
        # the arena (the reservation becomes the row's initial lease +
        # top-up entitlement once the refill lands)
        self._pending_pages += self._pages_needed(mid, r)
        super().place(mid, r, resume, quant)

    def step(self, env, k):
        finished, occupied, capacity = [], 0, 0
        # Refill clamps are computed BEFORE any pool mutates — the same
        # headroom view admission was gated on at this boundary (each
        # pool's OWN cohort headroom; the historical cross-pool MIN
        # clamp is gone — see ``node_headroom``).
        clamps = {mid: self.node_headroom(mid)
                  for mid, pool in self._pools.items()
                  if pool["pending"] and pool["state"] is not None}
        for mid, pool in self._pools.items():
            eng = pool["engine"]
            if pool["pending"]:
                # Split serving (DESIGN.md §1.1): a pending tagged with
                # a decided method only joins a cohort serving at that
                # method's canonical precision; untagged pendings are
                # method-agnostic.  Non-matching pendings stay HELD —
                # slots reserved — and form the next sub-batch, started
                # at their own method once this cohort drains.
                if pool["state"] is not None:
                    target = eng._canon_bits(pool["state"].bits)
                else:
                    q0 = pool["pending"][0][3]
                    if q0 is None:
                        q0 = pool["quant"]
                    elif pool["quant"] is None \
                            or q0.name != pool["quant"].name:
                        pool["quant"] = q0   # cohort accounting follows
                    cb = self._cohort_bits(pool)
                    target = eng.default_bits if cb is None \
                        else eng._canon_bits(cb)
                take, held = [], []
                for item in pool["pending"]:
                    q = item[3]
                    if q is None \
                            or eng._canon_bits(q.serve_bits) == target:
                        take.append(item)
                    else:
                        held.append(item)
                pool["pending"] = held
            else:
                take = []
            if take:
                slots = [s for s, _, _, _ in take]
                reqs = [r for _, r, _, _ in take]
                prompts, caps, prefixes = [], [], []
                for slot, r, resume, _ in take:
                    if resume is None:
                        # same rng draw order as the historical batched
                        # synth call — fresh admissions are bit-stable
                        p, c = eng.synth_prompts([r], self.rng)
                        prompts.append(p[0])
                        caps.append(c[0])
                        prefixes.append(None)
                    else:
                        # resume: re-prefill the ORIGINAL prompt and
                        # replay the delivered prefix bit-exactly via
                        # the engine's forced-prefix mechanism
                        prompts.append(resume["prompt"])
                        caps.append(min(r.n, eng.n_max))
                        prefixes.append(resume["prefix"])
                    pool["prompts"][slot] = prompts[-1]
                ff = max((len(p) for p in prefixes if p), default=0)
                if all(p is None for p in prefixes):
                    prefixes = None
                if pool["state"] is None:
                    pool["state"] = eng.start_chunked(
                        prompts, caps, quant_bits=self._cohort_bits(pool),
                        arena=self.arena if pool["paged"] else None,
                        prefixes=prefixes)
                    pool["t"] = 0
                else:
                    pool["state"] = eng.refill_chunked(
                        pool["state"], slots, prompts, caps,
                        t_now=pool["t"], cap_max=clamps[mid],
                        prefixes=prefixes)
                pool["resident"].update(zip(slots, reqs))
                if ff:
                    # Eager resume replay: the forced-prefix steps
                    # re-derive tokens the user ALREADY HAS, so they are
                    # burned here at the admitting boundary instead of
                    # consuming the segment grid's k-token budget — the
                    # deadline gate judges a resume on its REMAINING
                    # tokens (runtime._hopeless) and this is what makes
                    # that promise true on the engine path.  Token
                    # streams are unchanged (chunk-size invariance).
                    pool["state"] = eng.generate_chunked(pool["state"],
                                                         ff)
                    pool["t"] = min(pool["t"] + ff, eng.n_max)
        # landed reservations became real leases; re-reserve for pendings
        # still HELD for a later sub-batch (conservatively at the pool's
        # current cohort step)
        self._pending_pages = sum(
            self._pages_needed(mid, r)
            for mid, pool in self._pools.items()
            for _, r, _, _ in pool["pending"])
        for mid, pool in self._pools.items():
            eng = pool["engine"]
            occupied += len(pool["resident"])
            capacity += pool["capacity"]
            if pool["state"] is None:
                continue
            pool["state"] = eng.generate_chunked(pool["state"], k)
            # light poll: the hot path only needs the occupancy view,
            # not the (B, n_max) token buffer
            out, lengths, done, t = eng.poll_chunked(
                pool["state"], with_tokens=self.collect_tokens)
            pool["t"] = t
            caps_h = pool["state"].caps_host
            freed = []
            for slot, r in list(pool["resident"].items()):
                if done[slot] or lengths[slot] >= caps_h[slot]:
                    finished.append((mid, r, int(lengths[slot])))
                    if self.collect_tokens:
                        self.outputs[r.rid] = \
                            np.array(out[slot][:lengths[slot]])
                    del pool["resident"][slot]
                    pool["prompts"].pop(slot, None)
                    freed.append(slot)
            if pool["paged"] and freed:
                # release-on-completion: the freed pages are allocatable
                # by ANY cohort at the next admission boundary
                pool["state"] = eng.release_slots(pool["state"], freed)
            if not pool["resident"]:
                if pool["paged"]:
                    eng.release_all(pool["state"])
                pool["state"], pool["t"] = None, 0   # cohort drained
        return finished, occupied / capacity if capacity else 0.0

    def preempt(self, mid, rid):
        """Evict a resident row: spill its delivered tokens (one full
        poll), kill the row via ``evict_slots`` (paged leases return to
        the arena immediately), and hand back the original prompt plus
        the delivered prefix — everything resume needs to re-prefill and
        replay the request bit-exactly (DESIGN.md §2.4)."""
        pool = self._pools[mid]
        eng = pool["engine"]
        slot = next(s for s, r in pool["resident"].items() if r.rid == rid)
        out, lengths, done, t = eng.poll_chunked(pool["state"])
        prefix = [int(x) for x in out[slot][:lengths[slot]]]
        # tokens this row still owes AFTER the replayed prefix — the
        # deadline gate judges the resume on these, not the full n
        # (the replay itself is burned off-grid at the resuming
        # boundary; see the fast-forward in ``step``)
        remaining = max(0, int(pool["state"].caps_host[slot])
                        - len(prefix))
        pool["state"] = eng.evict_slots(pool["state"], [slot])
        del pool["resident"][slot]
        prompt = pool["prompts"].pop(slot)
        if not pool["resident"] and not pool["pending"]:
            if pool["paged"]:
                eng.release_all(pool["state"])
            pool["state"], pool["t"] = None, 0
        return {"prompt": prompt, "prefix": prefix,
                "remaining": remaining}

    def evacuate(self, mid):
        pool = self._pools[mid]
        eng = pool["engine"]
        removed = list(pool["resident"].values()) \
            + [r for _, r, _, _ in pool["pending"]]
        if pool["state"] is not None:
            eng.evict_slots(pool["state"], list(pool["resident"]))
            if pool["paged"]:
                eng.release_all(pool["state"])
        pool["resident"].clear()
        pool["pending"].clear()
        pool["prompts"].clear()
        pool["state"], pool["t"] = None, 0
        # NOTE: page reservations made for the cleared pendings stay in
        # ``_pending_pages`` until the next successful step resets it —
        # conservatively strict admission, never an arena overdraw.
        return removed

    def requant(self, mid, method):
        """Mid-flight cohort requant (DESIGN.md §2.4): on top of the
        base re-tagging, the LIVE decode state's ``bits`` are
        re-canonicalized so the very next segment's ``params_for``
        serves the re-scaled tree from the engine's multi-precision
        weight cache — a dict lookup, not a requantization pass.
        Historically degradation only re-selected methods for cohorts
        STARTING while degraded; resident cohorts kept serving at the
        pre-pressure method for their whole residency."""
        super().requant(mid, method)
        pool = self._pools[mid]
        if pool["state"] is not None:
            bits = method.serve_bits if method is not None \
                else self.quant_bits
            pool["state"] = dataclasses.replace(
                pool["state"],
                bits=pool["engine"]._canon_bits(bits))

    def topup_pages(self) -> int:
        return sum(getattr(e, "lease_topups", 0)
                   for e in self.engines.values())

    def block_usage(self):
        if self.arena is None:
            return super().block_usage()
        bt = self.arena.block_tokens
        live_tokens = 0
        for pool in self._pools.values():
            if pool.get("paged") and pool["state"] is not None:
                eng = pool["engine"]
                live_tokens += len(pool["resident"]) \
                    * (eng.s_max + pool["t"])
        alloc_tokens = self.arena.pages_in_use * bt
        return (self.arena.pages_in_use, self.arena.total_pages,
                live_tokens, alloc_tokens)


class ContinuousRuntime(EpochRuntime):
    """Continuous-batching sibling of the epoch loop (DESIGN.md §2.1).

    Same arrival / aging / viability-drop bookkeeping on the same epoch
    grid, but each epoch is split into ``segments_per_epoch`` chunked
    decode segments and ADMISSION happens at every segment boundary:
    first-fit over the queue in arrival order (``admission="fifo"``,
    the throughput default) or EDF-within-priority order
    (``admission="edf"``, the SLO stack — pair it with
    ``deadline_gated=True`` so overload does not burn slots on doomed
    tight-deadline work), each candidate
    gated by ``policy.validate()`` on (resident ∪ candidate) — the
    paper's P1 feasibility oracle reused as the admission-control
    contract, so no slot refill can violate the constraint set the
    scheduler enforces at epoch boundaries.  On a ``MultiLLMEnv`` the gate is NODE-WIDE: the
    joint resident batch across every hosted cohort is additionally
    re-checked against ``multi_feasible`` (raising
    ``InfeasibleDecisionError`` on a policy whose oracle is only
    per-model feasible), and each freshly started cohort's quantization
    method comes from ``policy.select_quant`` (the PR-2 descent for
    ``quant=auto``), recorded in ``EpochTrace.quants``.  Resident
    requests keep their admission-time waits; ``schedule()`` is never
    called — continuous batching replaces the batch-selection problem
    with per-request admission control.

    Requests are counted served when their generation FINISHES (the
    epoch runtime counts at selection; with its execute-within-the-epoch
    contract the two agree on epoch attribution).  After the last epoch
    the resident cohorts DRAIN to completion (bounded by one cohort
    span), attributed to the final epoch — so for ``warmup_epochs=0``
    conservation holds exactly, in its overload-hardened form
    (DESIGN.md §2.4)::

        arrived == served + dropped + shed
                   + len(final_queue_rids) + len(in_flight_rids)

    where ``shed`` is degradation/quarantine load shedding (distinct
    from viability drops) and ``in_flight_rids`` is empty except on the
    partial metrics a :class:`DrainStallError` carries.  Preemption
    (``preemption=True``) moves resident rows back to the queue with
    their progress spilled — the engine path resumes them by
    re-prefilling the ORIGINAL prompt and replaying the delivered
    prefix bit-exactly (forced-prefix decode; see
    ``ServingEngine._decode_chunk_fn``) — so preempted work is never
    double-counted in any bucket.  Transient data-plane faults
    (serving/faults.py) are retried up to ``retry_limit`` times per
    boundary; ``quarantine_after`` consecutive failures of one pool
    evacuate and quarantine it (shed, with accounting); ``watchdog_s``
    arms a wall-clock alarm around every step; and a
    :class:`DegradationController` lets the runtime trade precision for
    pressure relief with hysteresis.
    """

    def __init__(self, env: Env, policy: Union[str, SchedulerPolicy],
                 executor: ContinuousExecutor, k: int = 4,
                 segments_per_epoch: Optional[int] = None,
                 admission: str = "fifo",
                 deadline_gated: bool = False,
                 preemption: bool = False,
                 max_preemptions: int = 2,
                 backoff_boundaries: int = 2,
                 retry_limit: int = 3,
                 quarantine_after: int = 5,
                 watchdog_s: Optional[float] = None,
                 degradation: Optional[DegradationController] = None,
                 drain_limit: int = 100_000):
        super().__init__(env, policy)
        self.executor = self.cexec = executor
        self.k = int(k)
        self.segments_per_epoch = segments_per_epoch or max(
            1, math.ceil(executor.tokens_per_epoch() / self.k))
        # -- SLO / robustness knobs (DESIGN.md §2.4) -------------------------
        assert admission in ("edf", "fifo"), admission
        self.admission = admission          # queue order at admission:
                                            # EDF-within-priority or FIFO
        self.deadline_gated = deadline_gated  # skip candidates that
                                            # cannot finish by deadline
        self.preemption = preemption        # evict looser residents for
                                            # tighter candidates
        self.max_preemptions = max_preemptions    # eviction cap per request
        self.backoff_boundaries = backoff_boundaries  # resume backoff,
                                            # linear in attempts
        self.retry_limit = retry_limit      # step retries per boundary on
                                            # transient faults
        self.quarantine_after = quarantine_after  # consecutive pool
                                            # failures before quarantine
        self.watchdog_s = watchdog_s        # wall-clock deadline per step
                                            # (None = unarmed)
        self.degradation = degradation      # graceful-degradation
                                            # hysteresis (None = off)
        self.drain_limit = drain_limit      # post-run drain segments
                                            # before DrainStallError

    # -- admission: validate()-gated first-fit -------------------------------

    @property
    def _split_mode(self) -> bool:
        return bool(getattr(self.policy, "split", False))

    def _split_decision(self, batches: Dict[Optional[str], List[Request]],
                        quants: Dict[Optional[str], QuantMethod],
                        extra: Optional[Dict[int, QuantMethod]] = None
                        ) -> Decision:
        """Trial Decision for ``validate()``: under a split policy the
        per-model sub-batch structure is rebuilt from each resident
        row's DECIDED method (its placement tag, via
        ``cexec.decided_quant``; ``extra`` maps candidate rids not yet
        placed), so the oracle prices a mixed pool with the swap-aware
        split check instead of flattening it onto one method — the
        historical one-precision-per-cohort assumption this PR removes.
        Non-split policies get the plain flat Decision unchanged."""
        dec = Decision(batches=batches, quants=quants)
        if not self._split_mode:
            return dec
        extra = extra or {}
        for mid, batch in batches.items():
            if len(batch) < 2:
                continue
            default = quants.get(mid)
            groups: Dict[Optional[str], tuple] = {}
            for r in batch:
                q = extra[r.rid] if r.rid in extra \
                    else self.cexec.decided_quant(r.rid, default)
                key = q.name if q is not None else None
                groups.setdefault(key, ([], q))[0].append(r)
            if len(groups) > 1:
                dec.splits[mid] = [(b, q) for b, q in groups.values()]
        return dec

    def _assert_jointly_feasible(self, batches: Dict[Optional[str],
                                                     List[Request]],
                                 quants: Dict[Optional[str], QuantMethod]
                                 ) -> None:
        """Authoritative node-wide re-check on multi-LLM nodes: an
        admission boundary must leave the JOINT resident batch feasible
        under ``multi_feasible`` (shared spectrum, shared memory pool,
        sequential compute slot).  Per-model feasibility does not compose
        across cohorts on shared budgets — a policy whose oracle only
        checks its own model's view cheats the node and is caught here,
        at admission, before anything serves.  Run ONCE per boundary
        (not per candidate): every joint constraint is monotone in batch
        growth, so an infeasible intermediate state cannot become
        feasible again by the end of the loop — same detection at 1/N
        the oracle cost."""
        if not isinstance(self.env, MultiLLMEnv):
            return
        order = getattr(self.policy, "order", "weight")
        dec = self._split_decision(batches, quants)
        if not multi_feasible(self.env, batches, order=order,
                              quants=quants, splits=dec.splits or None,
                              swap_record=getattr(self.policy,
                                                  "_swap_record", None)):
            raise InfeasibleDecisionError(
                f"{self.policy.spec}: admission accepted a candidate "
                f"whose joint resident batch fails multi_feasible — "
                f"per-model feasibility does not compose on shared node "
                f"budgets")

    def _admission_order(self, queue: List[Request]) -> List[Request]:
        """The order admission considers the queue in: plain arrival
        order (``admission="fifo"``, the throughput default) or EDF
        within priority classes (``admission="edf"``, the SLO stack)."""
        return edf_order(queue) if self.admission == "edf" \
            else list(queue)

    def _hopeless(self, r: Request,
                  rec: Optional[SpillRecord]) -> bool:
        """Deadline-aware admission filter (``deadline_gated=True``):
        a candidate that cannot finish by its deadline even if served
        IMMEDIATELY — earliest finish = current boundary + one segment
        per k tokens — is never worth a slot.  Unlike the optimistic
        lone-compute bound ``still_viable`` drops on, this uses the
        runtime's own segment grid, so under overload EDF stops burning
        capacity on doomed tight-deadline work (the classic EDF overload
        collapse).  A spilled request — analytic OR engine — is judged
        on its REMAINING tokens: both preempt payloads carry
        ``"remaining"``, and the engine path burns the forced-prefix
        replay off-grid at the resuming boundary (the fast-forward in
        ``EngineContinuousExecutor.step``), so the remaining-token
        judgment is honest, not optimistic."""
        n = r.n
        if rec is not None and "remaining" in rec.payload:
            n = rec.payload["remaining"]
        dt = self.T_E / self.segments_per_epoch
        t_fin = self._tnow + math.ceil(max(1, int(n)) / self.k) * dt
        return t_fin > r.deadline + 1e-9

    def _degraded_quant(self, mid: Optional[str],
                        reqs: List[Request]) -> Optional[QuantMethod]:
        """Degraded-mode cohort method: the FASTEST admissible method
        for the prospective pool — accuracy floors stay binding
        (``candidate_methods`` prefilters on the batch's a_i), but the
        throughput-vs-accuracy descent is skipped in favor of minimum
        compute time (min beta) while the node is under pressure."""
        env_r = self.env.envs[mid] if isinstance(self.env, MultiLLMEnv) \
            else self.env
        cands = candidate_methods(
            env_r.model.arch_id,
            accuracies=[r.a for r in reqs] if reqs else None)
        return cands[0] if cands else None

    def _requant_live(self, m: EpochMetrics, trace: EpochTrace,
                      counting: bool,
                      queue: Sequence[Request] = ()) -> None:
        """Degradation RISING EDGE: re-select the serving method for
        LIVE cohorts too, not just cohorts that start while degraded —
        the historical gap left a mid-flight cohort serving at the
        pre-pressure method for its whole residency, so a long cohort
        admitted just before overload never degraded at all.  Each
        non-quarantined pool with residents gets the fastest method
        admissible for its resident batch AND the (post-shed) queued
        work headed its way (``_degraded_quant``) — flipping below the
        queue's accuracy demand would just trade overload for
        accuracy-starvation, since refills whose floor exceeds the
        cohort's method fail joint validation at every boundary until
        the pool drains.  If the pick differs from the cohort's current
        method and the oracle accepts the re-pointed joint batch, the
        executor requants the cohort mid-flight (``cexec.requant`` — on
        engines a multi-precision weight-cache lookup at the next
        segment) with explicit accounting (``EpochMetrics.requanted``);
        the pre-flip method is remembered for the falling-edge
        restore.

        Skipped entirely on serving-inert planes
        (``cexec.requant_effective`` False, e.g. the analytic
        executor): there a flip changes nothing the plane delivers
        while still loosening the oracle's admission bound — pure
        pricing optimism."""
        cexec = self.cexec
        if not cexec.requant_effective:
            return
        batches = {mm: cexec.resident(mm) for mm in cexec.pool_ids()}
        quants = {mm: q for mm in cexec.pool_ids()
                  if batches[mm] and (q := cexec.quant_of(mm)) is not None}
        for mid in cexec.pool_ids():
            if mid in self._quarantined or not batches[mid]:
                continue
            inbound = [r for r in queue
                       if getattr(r, "model_id", None) == mid]
            q = self._degraded_quant(mid, batches[mid] + inbound)
            cur = cexec.quant_of(mid)
            if q is None or (cur is not None and q.name == cur.name):
                continue
            trial = dict(quants)
            trial[mid] = q
            if not self.policy.validate(
                    self.env,
                    self._split_decision(
                        batches, trial,
                        extra={r.rid: q for r in batches[mid]})):
                continue
            self._requant_prior[mid] = (cur, q.name)
            cexec.requant(mid, q)
            quants = trial
            trace.quants[mid] = q.name
            if counting:
                m.requanted += 1

    def _requant_restore(self, m: EpochMetrics, trace: EpochTrace,
                         counting: bool) -> None:
        """Degradation FALLING edge: undo the rising-edge flips.  A
        requanted cohort otherwise keeps its degraded (fast,
        low-accuracy) method until its pool fully drains — and under
        continuous refill a pool may never drain, so queued work whose
        accuracy floor exceeds the degraded method's accuracy starves
        long after the pressure cleared (it fails joint validation
        against the cohort's method at every boundary).  Each pool
        whose rising-edge flip is still in effect is re-pointed at its
        pre-flip method under the same oracle gate; a pool that turned
        over since, or whose restore fails validation, keeps its
        current method — the next cohort start re-decides anyway."""
        cexec = self.cexec
        prior_map, self._requant_prior = self._requant_prior, {}
        batches = {mm: cexec.resident(mm) for mm in cexec.pool_ids()}
        quants = {mm: q for mm in cexec.pool_ids()
                  if batches[mm] and (q := cexec.quant_of(mm)) is not None}
        for mid, (prior, flipped) in prior_map.items():
            if mid in self._quarantined or not batches.get(mid):
                continue
            cur = cexec.quant_of(mid)
            if cur is None or cur.name != flipped:
                continue                  # cohort turned over since
            trial = dict(quants)
            if prior is None:
                trial.pop(mid, None)
            else:
                trial[mid] = prior
            if not self.policy.validate(
                    self.env,
                    self._split_decision(
                        batches, trial,
                        extra={r.rid: prior for r in batches[mid]})):
                continue
            cexec.requant(mid, prior)
            quants = trial
            env_r = self.env.envs[mid] \
                if isinstance(self.env, MultiLLMEnv) else self.env
            trace.quants[mid] = prior.name if prior is not None \
                else env_r.quant.name
            if counting:
                m.requanted += 1

    def _auto_calibrate(self) -> None:
        """Run-start warmup calibration (engine data planes only): a
        policy declaring ``calib="measured"`` with nothing installed
        gets a quick ``measure_beta`` pass on the hosted engine(s) —
        measured betas + measured weight-residency alphas
        (``attach_alphas``) — and a split policy with no swap record
        gets ``measure_swap_cost``, so ``dftsp:quant=auto,split=true``
        drives the continuous engine path with MEASURED coefficients
        out of the box instead of raising at the first descent."""
        engines = getattr(self.cexec, "engines", None)
        if not engines:
            return
        eng = next(iter(engines.values()))
        policy = self.policy
        if getattr(policy, "calib", None) == "measured" \
                and getattr(policy, "_measured", None) is None:
            from repro.quant.calibration import (attach_alphas,
                                                 measure_beta,
                                                 measured_methods)
            record = measure_beta(
                eng, batches=(1, min(4, eng.batch_capacity)), iters=1,
                n_tokens=4, prompt_len=4)
            attach_alphas(record, eng._raw_params)
            policy.install_measured(measured_methods(record))
        if getattr(policy, "split", False) \
                and getattr(policy, "_swap_record", None) is None:
            from repro.quant.calibration import measure_swap_cost
            policy.install_swap_costs(measure_swap_cost(eng, iters=1))

    def _try_admit(self, queue: List[Request], trace: EpochTrace,
                   degraded: bool = False) -> List[Request]:
        """Admit queued requests into free slots — first-fit in
        ``_admission_order`` — each gated by the policy's own
        feasibility oracle on the joint resident-plus-candidate batch —
        evaluated under every active cohort's decided quantization
        method — then re-checked against the joint ``multi_feasible``
        oracle on multi-LLM nodes.  The resident view is built once per
        boundary and updated incrementally as candidates land.

        The first admission into an empty pool STARTS a cohort: the
        policy picks its quantization method (``select_quant``, the
        PR-2 descent for ``quant=auto`` policies; the fastest
        admissible method while ``degraded``) over the queued requests
        targeting that model, the executor pins the cohort to it, and
        the choice is recorded in ``trace.quants``.

        Quarantined pools admit nothing, and a preempted request still
        inside its backoff window (``SpillRecord.not_before``) is
        skipped this boundary; when a spilled request IS re-admitted,
        its resume payload rides along so the executor restores the
        spilled progress."""
        admitted: List[Request] = []
        cexec = self.cexec
        batches = {m: cexec.resident(m) for m in cexec.pool_ids()}
        # methods the ACTIVE cohorts are being served with (a drained
        # pool's stale method is ignored: its next cohort re-decides)
        quants = {m: q for m in cexec.pool_ids()
                  if batches[m] and (q := cexec.quant_of(m)) is not None}
        fresh_sel: Dict[Optional[str], Optional[QuantMethod]] = {}
        for r in self._admission_order(queue):
            mid = r.model_id
            if mid in self._quarantined:
                continue
            rec = self._spills.get(r.rid)
            if rec is not None and self._boundary < rec.not_before:
                continue               # resume backoff not yet elapsed
            if self.deadline_gated and self._hopeless(r, rec):
                continue               # can't finish by deadline anyway
            if mid not in batches or not cexec.accepts(mid, r):
                continue
            starting = not batches[mid]
            if starting:
                if mid not in fresh_sel:
                    pool_reqs = [x for x in queue if x.model_id == mid]
                    fresh_sel[mid] = self._degraded_quant(mid, pool_reqs) \
                        if degraded else self.policy.select_quant(
                            self.env, mid, pool_reqs)
                q = fresh_sel[mid]
            else:
                q = quants.get(mid)
            batches[mid].append(r)
            trial = dict(quants)
            if q is not None:
                trial[mid] = q
            ok = self.policy.validate(
                self.env, self._split_decision(batches, trial,
                                               extra={r.rid: q}))
            if not ok and self._split_mode and not degraded:
                # SPLIT fallback (DESIGN.md §1.1): the candidate is
                # infeasible at the cohort's method — re-decide a method
                # for it ALONE and try it as its own sub-batch (the
                # executor holds it until the live sub-batch drains, so
                # differently-quantized rows serve back to back with
                # the swap cost priced by the split oracle)
                q2 = self.policy.select_quant(self.env, mid, [r])
                if q2 is not None and (q is None or q2.name != q.name):
                    trial2 = dict(quants)
                    if starting:
                        trial2[mid] = q2   # fresh cohort: start AT q2
                    elif q is not None:
                        trial2[mid] = q    # primary stays the cohort's
                    if self.policy.validate(
                            self.env,
                            self._split_decision(batches, trial2,
                                                 extra={r.rid: q2})):
                        ok, q, trial = True, q2, trial2
            if ok:
                if starting:
                    cexec.set_quant(mid, q)
                    if q is not None:
                        trace.quants[mid] = q.name
                quants = trial
                cexec.place(mid, r,
                            resume=rec.payload if rec is not None else None,
                            quant=q if self._split_mode else None)
                admitted.append(r)
            else:
                batches[mid].pop()
        if admitted:
            self._assert_jointly_feasible(batches, quants)
        return admitted

    def _try_preempt(self, queue: List[Request], trace: EpochTrace,
                     m: EpochMetrics, counting: bool
                     ) -> Tuple[List[Request], List[Request]]:
        """Priority preemption at a segment boundary (DESIGN.md §2.4).

        For each still-queued candidate (in admission order) whose
        admission is BOUND — its pool out of slots, or the shared KV
        arena refusing its pages (``arena_blocked``) — find a resident
        victim the candidate strictly beats (``pick_victim``: higher
        priority class, or same class with an earlier deadline), check
        the policy oracle still holds on the swapped batch, then evict
        the victim — spilling its progress into a :class:`SpillRecord`
        — and admit the candidate into the freed capacity.  When the
        pool is slot-bound, victims come from the candidate's own pool
        (a freed slot elsewhere is useless); when the ARENA binds,
        victims come from EVERY healthy pool — any cohort's released
        pages free the shared node budget, the cross-model eviction the
        historical intra-pool-only rule could not express (a
        high-priority admission was shed despite evictable low-priority
        pages in another cohort).  Eviction repeats until the candidate
        fits or no admissible victim remains (bounded: residents
        strictly shrink).  Victims re-enter the queue and resume later
        via their spill payload; a victim already evicted
        ``max_preemptions`` times is pinned (never evicted again), and
        each eviction pushes the victim's earliest re-admission out by
        ``backoff_boundaries × attempts`` segment boundaries.

        Returns ``(admitted_candidates, requeued_victims)``."""
        cexec = self.cexec
        admitted: List[Request] = []
        requeued: List[Request] = []
        if not queue:
            return admitted, requeued
        batches = {mm: cexec.resident(mm) for mm in cexec.pool_ids()}
        quants = {mm: q for mm in cexec.pool_ids()
                  if batches[mm] and (q := cexec.quant_of(mm)) is not None}
        changed = False
        for r in self._admission_order(queue):
            mid = r.model_id
            if mid in self._quarantined or mid not in batches:
                continue
            rec = self._spills.get(r.rid)
            if rec is not None and self._boundary < rec.not_before:
                continue           # candidate itself is backing off
            if self.deadline_gated and self._hopeless(r, rec):
                continue           # not worth evicting anyone for
            slot_bound = cexec.free_slots(mid) <= 0
            if not slot_bound and not cexec.arena_blocked(mid, r):
                continue           # not bound; admission had its shot
            vpools = [mid] if slot_bound else \
                [p for p in cexec.pool_ids() if p not in self._quarantined]
            while True:
                eligible = [v for p in vpools for v in cexec.evictable(p)
                            if (self._spills[v.rid].attempts
                                if v.rid in self._spills else 0)
                            < self.max_preemptions]
                victim = pick_victim(eligible, r)
                if victim is None:
                    break
                vmid = victim.model_id
                trial_batches = dict(batches)
                trial_batches[vmid] = [x for x in batches[vmid]
                                       if x.rid != victim.rid]
                trial_batches[mid] = trial_batches[mid] + [r]
                if not self.policy.validate(
                        self.env,
                        self._split_decision(trial_batches, quants)):
                    break
                payload = cexec.preempt(vmid, victim.rid)
                prev = self._spills.get(victim.rid)
                attempts = prev.attempts + 1 if prev is not None else 1
                self._spills[victim.rid] = SpillRecord(
                    request=victim, payload=payload, attempts=attempts,
                    not_before=self._boundary
                    + self.backoff_boundaries * attempts)
                requeued.append(victim)
                trace.preempted_rids.append(victim.rid)
                if counting:
                    m.preempted += 1
                changed = True
                batches[vmid] = [x for x in batches[vmid]
                                 if x.rid != victim.rid]
                if cexec.accepts(mid, r):
                    cexec.place(mid, r,
                                resume=rec.payload if rec is not None
                                else None)
                    admitted.append(r)
                    batches[mid] = batches[mid] + [r]
                    break
        if changed:
            self._assert_jointly_feasible(batches, quants)
        return admitted, requeued

    def _shed_queue(self, queue: List[Request], m: EpochMetrics,
                    trace: EpochTrace, counting: bool) -> List[Request]:
        """Degraded-mode load shedding: drop the controller's chosen
        lowest-priority queued work with explicit accounting (``shed``
        is a separate conservation bucket from viability drops)."""
        to_shed = self.degradation.shed_candidates(queue)
        if not to_shed:
            return queue
        gone = set()
        for r in to_shed:
            gone.add(r.rid)
            trace.shed_rids.append(r.rid)
            if counting:
                m.shed += 1
        return [r for r in queue if r.rid not in gone]

    def _quarantine(self, mid: Optional[str], m: EpochMetrics,
                    trace: EpochTrace, counting: bool) -> None:
        """Quarantine pool ``mid`` after ``quarantine_after`` consecutive
        step failures: evacuate everything it holds (shed, with
        accounting — cross-model redistribution is impossible since a
        request targets one hosted model), and stop admitting into it
        for the rest of the run."""
        removed = self.cexec.evacuate(mid)
        self._quarantined.add(mid)
        m.quarantined.append(str(mid))
        for r in removed:
            trace.shed_rids.append(r.rid)
            if counting:
                m.shed += 1
            self._first_token.pop(r.rid, None)
            self._spills.pop(r.rid, None)

    def _step_guarded(self, m: EpochMetrics, trace: EpochTrace,
                      counting: bool) -> Tuple[List, float, float]:
        """One data-plane step under the fault-handling contract:
        retry transient failures (raised BEFORE any state mutated, so a
        replay is safe) up to ``retry_limit`` times, trip the watchdog
        on steps exceeding ``watchdog_s`` wall seconds, and quarantine a
        pool after ``quarantine_after`` CONSECUTIVE failures.  A
        boundary whose retry budget is exhausted is skipped — no
        progress, but the loop survives and the next boundary retries.
        Returns ``(finished, occupancy, wall_s)``."""
        wall_total = 0.0
        for attempt in range(self.retry_limit + 1):
            t0 = time.perf_counter()
            try:
                finished, occ = self.cexec.step(self.env, self.k)
            except TransientStepError as e:
                wall_total += time.perf_counter() - t0
                trace.faults += 1
                if counting:
                    m.faults_injected += 1
                key = e.mid
                self._streaks[key] = self._streaks.get(key, 0) + 1
                if key in self.cexec.pool_ids() \
                        and key not in self._quarantined \
                        and self._streaks[key] >= self.quarantine_after:
                    self._quarantine(key, m, trace, counting)
                    self._streaks[key] = 0
                if attempt < self.retry_limit:
                    if counting:
                        m.retried += 1
                    continue
                return [], 0.0, wall_total
            wall = time.perf_counter() - t0
            wall_total += wall
            if self.watchdog_s is not None and wall > self.watchdog_s \
                    and counting:
                m.watchdog_trips += 1
            self._streaks.clear()   # a successful step ran every pool
            return finished, occ, wall_total
        return [], 0.0, wall_total  # unreachable; loop always returns

    def _record_blocks(self, counting: bool, m: EpochMetrics,
                       trace: EpochTrace) -> None:
        """Per-segment KV-block accounting (DESIGN.md §2.3): the
        executor's ``block_usage`` snapshot feeds the trace's in-use
        series and the run-level occupancy/fragmentation aggregates."""
        in_use, total, live_tok, alloc_tok = self.cexec.block_usage()
        trace.kv_blocks_in_use.append(in_use)
        trace.kv_blocks_total = total
        if counting:
            m.kv_alloc_tokens += alloc_tok
            m.kv_dead_tokens += max(0, alloc_tok - live_tok)
            m.kv_topup_pages = self.cexec.topup_pages() - self._topup0

    def _record_finished(self, finished: Sequence, counting: bool,
                         m: EpochMetrics, trace: EpochTrace,
                         now: Optional[float] = None) -> None:
        for mid, r, tokens in finished:
            trace.finished_rids.append(r.rid)
            trace.generated_tokens += tokens
            if counting:
                m.served += 1
                m.generated_tokens += tokens
                m.served_by_model[mid] = \
                    m.served_by_model.get(mid, 0) + 1
                name = self.cexec.method_name(mid, self._env_for(r),
                                              rid=r.rid)
                m.served_by_method[name] = \
                    m.served_by_method.get(name, 0) + 1
            if now is None:
                continue
            # SLO accounting in simulated time (DESIGN.md §2.4): the
            # request completes at the END of the segment it finished
            # in; its first token landed at the end of the segment that
            # admitted it.
            lat = now - r.arrival
            met = lat <= r.tau + 1e-9
            if counting:
                m.latencies.append(lat)
                if met:
                    m.slo_met += 1
                ft = self._first_token.get(r.rid)
                if ft is not None:
                    m.ttfts.append(ft - r.arrival)
                    if tokens > 1 and now > ft:
                        m.tpots.append((now - ft) / (tokens - 1))
            if self.degradation is not None:
                self.degradation.record_finish(met)
            self._first_token.pop(r.rid, None)
            self._spills.pop(r.rid, None)

    def run(self, rate: Optional[float] = None, n_epochs: int = 30,
            seed: int = 0, gen: Optional[RequestGenerator] = None,
            warmup_epochs: int = 1,
            tag_arrivals: Optional[Callable[[List[Request]],
                                            List[Request]]] = None
            ) -> EpochMetrics:
        gen = self._resolve_gen(rate, seed, gen)
        T_E = self.T_E
        n_seg = self.segments_per_epoch
        dt = T_E / n_seg
        self.cexec.bind(self.env)
        self._auto_calibrate()
        self._topup0 = self.cexec.topup_pages()   # engines may be reused
        m = EpochMetrics(n_epochs=n_epochs, T_E=T_E)
        queue: List[Request] = []
        trace: Optional[EpochTrace] = None
        # per-run SLO / robustness state (DESIGN.md §2.4)
        self._spills: Dict[int, SpillRecord] = {}
        self._quarantined: set = set()
        self._streaks: Dict[Optional[str], int] = {}
        self._boundary = 0              # global segment-boundary index
        self._first_token: Dict[int, float] = {}
        self._tnow = 0.0                # current boundary's segment start
        self._was_degraded = False      # degradation edge detector
        self._requant_prior = {}        # mid -> (pre-flip method, name)
        now = 0.0

        for e in range(n_epochs + warmup_epochs):
            counting = e >= warmup_epochs
            trace = EpochTrace(epoch=e, arrived=0, dropped=0,
                               selected_rids=[], counted=counting)
            for j in range(n_seg):
                t_seg = e * T_E + j * dt
                self._tnow = t_seg
                now = t_seg + dt
                # requests that arrived during the previous SEGMENT join
                # here — the epoch loop's boundary rule, at segment grain
                arrivals = gen.within(t_seg - dt, t_seg) if (e or j) else []
                if tag_arrivals is not None:
                    arrivals = tag_arrivals(arrivals)
                trace.arrived += len(arrivals)
                if counting:
                    m.arrived += len(arrivals)
                queue.extend(arrivals)

                queue, n_dropped = self._age_and_drop(queue, t_seg)
                trace.dropped += n_dropped
                if counting:
                    m.dropped += n_dropped

                # graceful degradation: advance the hysteresis, and in
                # degraded mode shed the controller's lowest-priority
                # queued work before admission considers it
                degraded = False
                if self.degradation is not None:
                    degraded = self.degradation.observe(len(queue))
                    if degraded:
                        if counting:
                            m.degraded_segments += 1
                        queue = self._shed_queue(queue, m, trace,
                                                 counting)
                        if not self._was_degraded:
                            # rising edge: LIVE cohorts degrade too,
                            # not just the ones that start from now on
                            self._requant_live(m, trace, counting,
                                               queue)
                    elif self._was_degraded and self._requant_prior:
                        # falling edge: restore the pre-flip methods so
                        # high-accuracy queued work stops starving
                        self._requant_restore(m, trace, counting)
                    self._was_degraded = degraded

                admitted = self._try_admit(queue, trace, degraded)
                if self.preemption:
                    got = {r.rid for r in admitted}
                    rest = [r for r in queue if r.rid not in got]
                    preempt_admits, requeued = self._try_preempt(
                        rest, trace, m, counting)
                    admitted = admitted + preempt_admits
                if admitted:
                    got = {r.rid for r in admitted}
                    queue = [r for r in queue if r.rid not in got]
                    trace.selected_rids.extend(r.rid for r in admitted)
                    if j > 0:
                        trace.admitted_mid_epoch += len(admitted)
                        if counting:
                            m.admitted_mid_epoch += len(admitted)
                    for r in admitted:
                        if r.rid in self._spills and counting:
                            m.resumed += 1
                        self._first_token.setdefault(r.rid, now)
                if self.preemption and requeued:
                    queue.extend(requeued)

                finished, occ, wall = self._step_guarded(m, trace,
                                                         counting)
                self._boundary += 1
                trace.wall_s += wall
                trace.segments += 1
                trace.occupancy.append(occ)
                self._record_blocks(counting, m, trace)
                if counting:
                    m.segments += 1
                self._record_finished(finished, counting, m, trace,
                                      now=now)

            if counting:
                m.batch_sizes.append(len(trace.selected_rids))
                m.wall_s += trace.wall_s
            m.traces.append(trace)

        # drain resident cohorts (bounded: every healthy step makes
        # progress and nothing new is admitted), attributed to the final
        # epoch; simulated time keeps advancing on the segment grid so
        # drain-finishing requests get honest latencies
        counting = n_epochs > 0
        for _ in range(self.drain_limit):
            if self.cexec.idle():
                break
            finished, occ, wall = self._step_guarded(m, trace, counting)
            self._boundary += 1
            now += dt
            trace.wall_s += wall
            trace.segments += 1
            trace.occupancy.append(occ)
            self._record_blocks(counting, m, trace)
            if counting:
                m.segments += 1
                m.wall_s += wall
            self._record_finished(finished, counting, m, trace, now=now)
        else:
            # a stalled drain still hands back everything it knows: the
            # partial metrics (with the rows still resident named in
            # ``in_flight_rids``) ride on the typed error, keeping the
            # conservation equation checkable from the exception alone
            m.final_queue_rids = [r.rid for r in queue]
            m.in_flight_rids = [r.rid for mid in self.cexec.pool_ids()
                                for r in self.cexec.resident(mid)]
            raise DrainStallError(
                f"continuous drain did not converge within "
                f"{self.drain_limit} segments "
                f"({len(m.in_flight_rids)} rows in flight)",
                metrics=m, resident_rids=m.in_flight_rids)

        m.final_queue_rids = [r.rid for r in queue]
        return m
