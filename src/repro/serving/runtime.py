"""EpochRuntime: THE epoch/queue lifecycle loop (paper Fig. 2 + §IV).

Historically the protocol — arrivals join at the epoch boundary, queued
requests age, hopeless requests drop, a scheduler picks a batch, served
requests leave — was hand-rolled three times (analytic sim, real-engine
serving, multi-LLM benchmarks) with drifting semantics.  It now lives
here exactly once, parameterized on two axes:

  * control plane — a ``SchedulerPolicy`` (core/policy.py): what to batch,
    WITH WHICH QUANTIZATION METHOD (``Decision.quants``), and the
    feasibility oracle the runtime re-checks it against;
  * data plane — an ``Executor``: how a decision is carried out.
    ``AnalyticExecutor`` charges cost-model time only (the paper's
    figures); ``EngineExecutor`` runs each batch on real JAX models via
    ``ServingEngine.generate`` — at the decision's precision, through the
    engine's multi-precision weight cache — clamping to engine capacity
    with a feasibility re-check and spill accounting instead of the old
    silent truncation.

The epoch loop records each epoch's decided method per model in its
``EpochTrace.quants`` and aggregates ``EpochMetrics.served_by_method``,
so adaptive-precision runs are auditable epoch by epoch.  It also times
every ``executor.execute`` call (``EpochTrace.wall_s``, aggregated into
``EpochMetrics.wall_s`` / ``tokens_per_s``) — under ``EngineExecutor``
that is the real data plane's measured decode throughput, since
``ServingEngine.generate`` blocks on its single device→host transfer.  (The historical
``simulate`` / ``serve_epochs`` / ``sweep`` shims are gone; drive this
class directly.)

``ContinuousRuntime`` is the iteration-level sibling: the same queue
lifecycle, but the data plane (a ``ContinuousExecutor``) runs chunked
decode segments and ADMITS queued requests at every segment boundary —
each slot refill gated by ``policy.validate()`` on the joint
resident-plus-candidate batch, so the paper's P1 constraints still hold
for everything on the device.  On a ``MultiLLMEnv`` the executor keeps
one device-resident cohort PER HOSTED ENGINE and every admission is
additionally re-checked against the authoritative joint oracle
(``multi.multi_feasible``) — per-model feasibility does not compose on
shared node budgets, and a policy that pretends it does raises
``InfeasibleDecisionError`` instead of serving.  Each freshly started
cohort picks its quantization method through the policy's
``select_quant`` (the PR-2 ``quant=auto`` descent on the continuous
path), served via the engine's multi-precision weight cache and
recorded in ``EpochTrace.quants``.  See DESIGN.md §2.1/§2.2.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.environment import EdgeEnv
from repro.core.metrics import EpochMetrics, EpochTrace
from repro.core.multi import MultiLLMEnv, multi_feasible
from repro.core.policy import (Decision, InfeasibleDecisionError,
                               SchedulerPolicy, as_policy)
from repro.core.quantization import QuantMethod
from repro.core.request import Request, RequestGenerator

Env = Union[EdgeEnv, MultiLLMEnv]


def still_viable(env: EdgeEnv, r: Request, now: float) -> bool:
    """Could this queued request still meet its deadline if scheduled at the
    *next* epoch boundary?  Lower bound: comm slots + its lone compute at
    its true prompt length (<= any batched/padded execution).

    The bound is computed under the env's deployed method even when a
    policy selects quant per epoch — it is a drop heuristic, and keeping
    it method-independent keeps fixed- and adaptive-method runs on the
    same queue trajectory for like-for-like comparison."""
    t_w = now - r.arrival
    cm = env.cost_model()
    lone = env.quant.beta * (cm.prefill_flops(r.s, 1)
                             + cm.decode_flops(r.s, [r.n])) / env.C
    return t_w + env.T_U + lone + env.T_D <= r.tau + 1e-12


# ---------------------------------------------------------------------------
# Executors: the data plane behind a scheduling decision
# ---------------------------------------------------------------------------


class Executor:
    """How a scheduling decision is carried out each epoch."""

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        """Clamp a decision to this data plane's capacity.  Returns the
        (possibly reduced) decision plus the spilled requests, which stay
        in the queue for later epochs."""
        return decision, []

    def execute(self, env: Env, decision: Decision) -> int:
        """Run the decision; returns the number of generated tokens."""
        raise NotImplementedError


class AnalyticExecutor(Executor):
    """Cost-model-time execution: nothing runs, latency/memory are charged
    analytically (P1's constraints).  The paper's evaluation path."""

    def execute(self, env: Env, decision: Decision) -> int:
        return 0


class EngineExecutor(Executor):
    """Real data plane: each batch executes on a ``ServingEngine``
    (batched prefill + decode on the JAX model).

    ``engines`` is one engine (single-model node) or a dict keyed by
    ``model_id`` mirroring a MultiLLMEnv's hosted deployments.  Batches
    larger than an engine's static ``batch_capacity`` are clamped and the
    spill is reported to the runtime (re-queued + counted) — the clamped
    batch is re-validated against the policy's own oracle rather than
    trusted silently.

    When a decision carries a quant assignment, each batch executes at
    that method's weight precision via the engine's multi-precision
    weight cache (``ServingEngine.params_for``) — the decided precision
    actually reaches the Pallas dequant-matmul kernel.
    """

    def __init__(self, engines, rng: Optional[np.random.Generator] = None,
                 seed: int = 0):
        if not isinstance(engines, dict):
            engines = {None: engines}
        self.engines = engines
        self.rng = rng if rng is not None else np.random.default_rng(seed)

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        spilled: List[Request] = []
        batches = {}
        for mid, batch in decision.batches.items():
            cap = self.engines[mid].batch_capacity
            batches[mid] = batch[:cap]
            spilled.extend(batch[cap:])
        if not spilled:
            return decision, []
        clamped = Decision(batches=batches, stats=decision.stats,
                           quants=decision.quants)
        # Feasibility is monotone under request removal for every shipped
        # policy, but the oracle is the contract — re-check, don't assume.
        if not policy.validate(env, clamped):
            raise InfeasibleDecisionError(
                f"{policy.spec}: capacity-clamped batch failed its own "
                f"oracle")
        return clamped, spilled

    def execute(self, env: Env, decision: Decision) -> int:
        tokens = 0
        for mid, batch in decision.batches.items():
            if not batch:
                continue
            engine = self.engines[mid]
            prompts, caps = engine.synth_prompts(batch, self.rng)
            q = decision.quants.get(mid)
            result = engine.generate(
                prompts, caps,
                quant_bits=None if q is None else q.serve_bits)
            tokens += int(result.lengths.sum())
        return tokens


# ---------------------------------------------------------------------------
# The one control loop
# ---------------------------------------------------------------------------


class EpochRuntime:
    """Drives the epoch protocol for any (env, policy, executor) triple."""

    def __init__(self, env: Env, policy: Union[str, SchedulerPolicy],
                 executor: Optional[Executor] = None):
        self.env = env
        self.policy = as_policy(policy)
        self.executor = executor or AnalyticExecutor()

    @property
    def T_E(self) -> float:
        return self.env.T_E

    def _env_for(self, r: Request) -> Optional[EdgeEnv]:
        """The single-model constraint view serving this request."""
        if isinstance(self.env, MultiLLMEnv):
            return self.env.env_for(r)
        return self.env

    @staticmethod
    def _resolve_gen(rate: Optional[float], seed: int,
                     gen: Optional[RequestGenerator]) -> RequestGenerator:
        """The ONE default workload (paper §IV marginals) — shared by the
        epoch and continuous loops so their traffic stays comparable."""
        if gen is not None:
            return gen
        if rate is None:
            raise ValueError("provide either rate= or gen=")
        return RequestGenerator(rate=rate, seed=seed,
                                lengths=(128, 256, 512))

    def _age_and_drop(self, queue: List[Request], now: float
                      ) -> Tuple[List[Request], int]:
        """Age every queued request to ``now`` and drop the hopeless (or
        untargeted) ones — the ONE copy of the viability bookkeeping,
        shared by the epoch and continuous loops so their queue
        trajectories cannot drift."""
        viable: List[Request] = []
        dropped = 0
        for r in queue:
            r.t_w = now - r.arrival
            env_r = self._env_for(r)
            if env_r is not None and still_viable(env_r, r, now):
                viable.append(r)
            else:
                dropped += 1
        return viable, dropped

    def run(self, rate: Optional[float] = None, n_epochs: int = 30,
            seed: int = 0, gen: Optional[RequestGenerator] = None,
            warmup_epochs: int = 1,
            tag_arrivals: Optional[Callable[[List[Request]],
                                            List[Request]]] = None
            ) -> EpochMetrics:
        """Run the epoch protocol with Poisson(rate) arrivals.

        The first ``warmup_epochs`` epochs run but are excluded from the
        aggregate metrics (queue fill-up transient).  ``tag_arrivals``
        lets multi-LLM workloads assign each arrival a ``model_id``.
        """
        gen = self._resolve_gen(rate, seed, gen)
        T_E = self.T_E
        m = EpochMetrics(n_epochs=n_epochs, T_E=T_E)
        queue: List[Request] = []

        for e in range(n_epochs + warmup_epochs):
            t0 = e * T_E
            counting = e >= warmup_epochs
            # requests that arrived during the previous epoch join the queue
            arrivals = gen.within(t0 - T_E, t0) if e else []
            if tag_arrivals is not None:
                arrivals = tag_arrivals(arrivals)
            if counting:
                m.arrived += len(arrivals)
            queue.extend(arrivals)

            # age the queue; drop hopeless (or untargeted) requests
            queue, n_dropped = self._age_and_drop(queue, t0)
            if counting:
                m.dropped += n_dropped

            decision = self.policy.schedule(self.env, queue)
            decision, spilled = self.executor.admit(self.env, self.policy,
                                                    decision)
            # authoritative re-check against the policy's own oracle
            # (schedulers must not cheat)
            if not self.policy.validate(self.env, decision):
                raise InfeasibleDecisionError(
                    f"{self.policy.spec} returned an infeasible batch")
            # real executors block on the result (ServingEngine.generate
            # device_gets), so this wall-clock is the data plane's t_A+t_I
            t_exec = time.perf_counter()
            tokens = self.executor.execute(self.env, decision)
            wall_s = time.perf_counter() - t_exec

            sel = decision.selected
            # the method each served model actually ran with this epoch
            quants = {mid: decision.quant_for(mid, self.env).name
                      for mid, batch in decision.batches.items() if batch}
            if counting:
                m.served += len(sel)
                m.batch_sizes.append(len(sel))
                m.nodes_visited += decision.stats.nodes_visited
                m.leaves_checked += decision.stats.leaves_checked
                m.truncated += len(spilled)
                m.generated_tokens += tokens
                m.wall_s += wall_s
                for mid, batch in decision.batches.items():
                    if batch:
                        name = quants[mid]
                        m.served_by_method[name] = \
                            m.served_by_method.get(name, 0) + len(batch)
                        m.served_by_model[mid] = \
                            m.served_by_model.get(mid, 0) + len(batch)
            m.traces.append(EpochTrace(
                epoch=e, arrived=len(arrivals), dropped=n_dropped,
                selected_rids=[r.rid for r in sel], truncated=len(spilled),
                nodes_visited=decision.stats.nodes_visited,
                generated_tokens=tokens, counted=counting,
                quants=quants, wall_s=wall_s))

            chosen = {r.rid for r in sel}
            queue = [r for r in queue if r.rid not in chosen]
        m.final_queue_rids = [r.rid for r in queue]
        return m


# ---------------------------------------------------------------------------
# Continuous batching: chunked decode segments + mid-epoch admission
# ---------------------------------------------------------------------------


class ContinuousExecutor:
    """Slot-structured data plane behind ``ContinuousRuntime``.

    One POOL of ``capacity`` request slots per hosted model.  Resident
    requests advance ``k`` tokens per ``step`` (one chunked decode
    segment); rows that finish free their slot, and freed slots are
    refillable between segments — the iteration-level batching the
    epoch protocol cannot express.  Subclasses implement the token
    mechanics; this base owns the slot bookkeeping shared by both.
    """

    def __init__(self):
        self._pools: Dict[Optional[str], dict] = {}

    # -- pool construction ---------------------------------------------------

    def bind(self, env: Env) -> None:
        """(Re)build one empty pool per hosted model of ``env``."""
        mids = list(env.envs) if isinstance(env, MultiLLMEnv) else [None]
        self._pools = {mid: self._make_pool(mid) for mid in mids}

    def _make_pool(self, mid: Optional[str]) -> dict:
        return {"capacity": self._capacity(mid), "resident": {},
                "pending": [], "quant": None}

    def _capacity(self, mid: Optional[str]) -> int:
        raise NotImplementedError

    # -- slot bookkeeping (shared) -------------------------------------------

    def pool_ids(self) -> List[Optional[str]]:
        return list(self._pools)

    def resident(self, mid: Optional[str]) -> List[Request]:
        """Requests currently occupying slots (incl. pending refills) —
        the batch an admission candidate must stay jointly feasible
        with."""
        pool = self._pools[mid]
        return list(pool["resident"].values()) \
            + [r for _, r in pool["pending"]]

    def free_slots(self, mid: Optional[str]) -> int:
        pool = self._pools[mid]
        return pool["capacity"] - len(pool["resident"]) \
            - len(pool["pending"])

    def accepts(self, mid: Optional[str], r: Request) -> bool:
        """Slot-structure gate only (P1 feasibility is the runtime's
        job, via ``policy.validate``)."""
        return mid in self._pools and self.free_slots(mid) > 0

    def place(self, mid: Optional[str], r: Request) -> None:
        """Claim the lowest free slot for an admitted request; the refill
        executes at the start of the next ``step`` (engines batch all of
        a boundary's admissions into ONE prefill)."""
        pool = self._pools[mid]
        taken = set(pool["resident"]) | {s for s, _ in pool["pending"]}
        slot = min(s for s in range(pool["capacity"]) if s not in taken)
        pool["pending"].append((slot, r))

    def idle(self) -> bool:
        return all(not p["resident"] and not p["pending"]
                   for p in self._pools.values())

    def block_usage(self) -> Tuple[int, int, int, int]:
        """KV-block accounting snapshot, recorded by the runtime after
        every segment: ``(blocks_in_use, blocks_total, live_tokens,
        alloc_tokens)``.  Data planes without a physical block pool
        (analytic, slab engines) report slot-level occupancy — one
        "block" per resident request against the node's slot capacity,
        with no token accounting (0, 0).  The arena-backed engine
        executor overrides this with true page counts, and
        ``alloc_tokens - live_tokens`` is the allocated-but-dead volume
        behind ``EpochMetrics.fragmentation``."""
        occupied = sum(len(p["resident"]) for p in self._pools.values())
        capacity = sum(p["capacity"] for p in self._pools.values())
        return occupied, capacity, 0, 0

    # -- per-cohort quantization lifecycle -----------------------------------

    def set_quant(self, mid: Optional[str],
                  method: Optional[QuantMethod]) -> None:
        """Record the method the cohort STARTING in pool ``mid`` is served
        with (``None`` = the deployment default).  Called by the runtime
        at the first admission into an empty pool; the value sticks for
        the cohort's whole life (refills join at the cohort's precision)
        and is overwritten when the next cohort starts."""
        self._pools[mid]["quant"] = method

    def quant_of(self, mid: Optional[str]) -> Optional[QuantMethod]:
        """The method the pool's current cohort is served with (None =
        deployment default)."""
        return self._pools[mid]["quant"]

    def method_name(self, mid: Optional[str], env_r: EdgeEnv) -> str:
        """Label for ``served_by_method`` accounting: the precision this
        pool's cohort actually serves with — the per-cohort decided
        method if one was set, else the env's deployed method (engine
        subclasses may add engine-level overrides)."""
        q = self._pools[mid]["quant"]
        return q.name if q is not None else env_r.quant.name

    # -- token mechanics (subclass contract) ---------------------------------

    def tokens_per_epoch(self) -> int:
        """Decode steps one epoch is provisioned for (sets the default
        segment grid: ``segments_per_epoch = ceil(tokens_per_epoch/k)``,
        so chunk size k = tokens_per_epoch reduces to one admission point
        per epoch — the epoch protocol's grid)."""
        raise NotImplementedError

    def step(self, env: Env, k: int
             ) -> Tuple[List[Tuple[Optional[str], Request, int]], float]:
        """Apply pending refills, advance every pool by at most ``k``
        tokens, and return (finished rows as ``(model_id, request,
        generated_tokens)``, mean occupied-slot fraction during the
        segment)."""
        raise NotImplementedError


class AnalyticContinuousExecutor(ContinuousExecutor):
    """Cost-model-time continuous data plane: nothing runs, resident
    requests emit ``k`` tokens per segment and finish after ``n_i`` —
    the deterministic vehicle for the conservation property tests (like
    ``AnalyticExecutor``, it reports 0 generated tokens)."""

    def __init__(self, capacity: Union[int, Dict[Optional[str], int]] = 8,
                 tokens_per_epoch_: int = 512):
        super().__init__()
        self._cap = capacity
        self._tokens_per_epoch = tokens_per_epoch_

    def _make_pool(self, mid):
        pool = super()._make_pool(mid)
        pool["remaining"] = {}          # slot -> output tokens left
        return pool

    def _capacity(self, mid: Optional[str]) -> int:
        return self._cap[mid] if isinstance(self._cap, dict) else self._cap

    def tokens_per_epoch(self) -> int:
        return self._tokens_per_epoch

    def step(self, env, k):
        finished, occupied, capacity = [], 0, 0
        for mid, pool in self._pools.items():
            for slot, r in pool["pending"]:
                pool["resident"][slot] = r
                pool["remaining"][slot] = r.n
            pool["pending"].clear()
            occupied += len(pool["resident"])
            capacity += pool["capacity"]
            for slot, r in list(pool["resident"].items()):
                pool["remaining"][slot] -= k
                if pool["remaining"][slot] <= 0:
                    finished.append((mid, r, 0))
                    del pool["resident"][slot]
                    del pool["remaining"][slot]
        return finished, occupied / capacity if capacity else 0.0


class EngineContinuousExecutor(ContinuousExecutor):
    """Real continuous data plane: each pool is a ``ServingEngine``
    COHORT driven through the chunked decode API.

    Admissions buffered by ``place`` become ONE prefill at the next
    ``step`` — ``start_chunked`` for an empty pool, ``refill_chunked``
    spliced into the live cohort otherwise.  Each segment is one jitted
    ``generate_chunked`` call plus one small ``poll_chunked`` readback
    (the per-segment host sync that buys the admission point).  A row
    finishes when EOS fires or its cap fills; when a cohort drains (or
    its shared cache position exhausts at ``n_max``) the pool resets and
    the next admission starts a fresh cohort.  ``accepts`` additionally
    requires the cohort headroom to cover a candidate's full clamped
    service ``min(n_i, n_max)`` so refills are never silently truncated.

    ``engines`` is one engine or a ``{model_id: ServingEngine}`` dict
    keyed like the hosted ``MultiLLMEnv`` (mirroring ``EngineExecutor``)
    — ONE device-resident cohort per hosted engine, all advancing on the
    node's shared segment grid.  Refill caps are clamped to the target
    cohort's OWN remaining headroom (``node_headroom``); cross-cohort
    memory pressure is expressed through the paged KV ``arena`` when one
    is attached — each admission must reserve its worst-case pages from
    the node-wide pool, and pages released by ANY cohort's completed
    rows are immediately allocatable by every other (the historical
    min-headroom clamp that let one long-running cohort throttle every
    model's admission is gone; DESIGN.md §2.3).

    Each cohort's served precision is the runtime-decided method
    (``set_quant``, from ``policy.select_quant`` at cohort start) via
    the engine's multi-precision weight cache; ``quant_bits`` optionally
    pins an engine-level fallback for cohorts with no decided method —
    an override, not a scheduled method, so ``served_by_method`` records
    it as ``"weight_bits=<b>"`` rather than borrowing a METHODS name
    whose beta/accuracy terms were never applied.
    """

    def __init__(self, engines, rng: Optional[np.random.Generator] = None,
                 seed: int = 0, quant_bits: Optional[int] = None,
                 collect_tokens: bool = False, arena=None):
        super().__init__()
        if not isinstance(engines, dict):
            engines = {None: engines}
        self.engines = engines
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.quant_bits = quant_bits
        # node-wide paged KV arena (serving/kv_arena.py): pools whose
        # engine can serve paged run arena-backed cohorts, admission
        # gated by page reservation instead of the min-headroom clamp
        self.arena = arena
        self._pending_pages = 0
        # rid -> generated token ids, filled at completion when enabled
        # (one full poll per segment instead of the light occupancy poll
        # — equivalence tests only; leave off on the hot path)
        self.collect_tokens = collect_tokens
        self.outputs: Dict[int, np.ndarray] = {}

    def _make_pool(self, mid):
        if mid not in self.engines:
            raise KeyError(
                f"no ServingEngine bound for hosted model {mid!r}; "
                f"executor hosts {sorted(map(str, self.engines))}")
        pool = super()._make_pool(mid)
        eng = self.engines[mid]
        paged = self.arena is not None and eng.paged_capable \
            and eng.cache_len % self.arena.block_tokens == 0
        pool.update(engine=eng, state=None, t=0, paged=paged)
        return pool

    def _capacity(self, mid) -> int:
        return self.engines[mid].batch_capacity

    def tokens_per_epoch(self) -> int:
        return max(e.n_max for e in self.engines.values())

    def method_name(self, mid, env_r: EdgeEnv) -> str:
        q = self._pools[mid]["quant"]
        if q is not None:
            return q.name
        if self.quant_bits is None:
            return env_r.quant.name
        return f"weight_bits={self.quant_bits}"

    def _cohort_bits(self, pool):
        """Precision spec a starting cohort is served at: the decided
        method's ``serve_bits`` (an int, or a (w, a) pair for W8A8 —
        routed to the engine's int8-activation tier), else the
        engine-level override, else None (the engine default)."""
        q = pool["quant"]
        return q.serve_bits if q is not None else self.quant_bits

    def node_headroom(self, mid) -> int:
        """Output tokens a refill into ``mid`` can be promised: the
        target pool's OWN cohort headroom (``n_max`` for a fresh
        cohort).  Historically this was clamped to the MINIMUM headroom
        across every live cohort on the node — a blunt provisioning
        proxy under which one long-running cohort throttled every
        model's admission.  The paged arena replaced that proxy with
        true per-block accounting: cross-cohort memory pressure is now
        expressed as page reservations (``accepts`` asks the arena
        whether the candidate's worst-case pages fit), and the paper's
        joint constraints stay with the authoritative ``multi_feasible``
        oracle at admission — so another cohort's AGE no longer caps
        this cohort's refill promises (DESIGN.md §2.3)."""
        pool = self._pools[mid]
        eng = self.engines[mid]
        return eng.n_max if pool["state"] is None \
            else eng.headroom(pool["t"])

    def _pages_needed(self, mid, fresh_rows: int = 1) -> int:
        """Worst-case arena pages one admission into ``mid`` reserves at
        the next boundary (0 for slab pools)."""
        pool = self._pools[mid]
        if not pool.get("paged"):
            return 0
        eng = pool["engine"]
        t = 0 if pool["state"] is None else pool["t"]
        return eng.pages_for_admission(t, self.arena.block_tokens) \
            * fresh_rows

    def accepts(self, mid, r) -> bool:
        if not super().accepts(mid, r):
            return False
        pool = self._pools[mid]
        if pool.get("paged"):
            # per-block admission: can this request's worst-case pages
            # be reserved, on top of boundary admissions already
            # pending?  (The multi_feasible oracle stays authoritative
            # for the paper's constraints — this gates physical KV.)
            need = self._pages_needed(mid)
            if self.arena.free_pages - self._pending_pages < need:
                return False
        if pool["state"] is None:
            return True     # fresh cohort: full n_max headroom of its own
        return self.node_headroom(mid) >= min(r.n, pool["engine"].n_max)

    def place(self, mid, r):
        # reserve the candidate's worst-case pages against this boundary
        # so a burst of same-boundary admissions can't jointly overdraw
        # the arena (released again once the refill actually leases)
        self._pending_pages += self._pages_needed(mid)
        super().place(mid, r)

    def step(self, env, k):
        finished, occupied, capacity = [], 0, 0
        # Refill clamps are computed BEFORE any pool mutates — the same
        # headroom view admission was gated on at this boundary (each
        # pool's OWN cohort headroom; the historical cross-pool MIN
        # clamp is gone — see ``node_headroom``).
        clamps = {mid: self.node_headroom(mid)
                  for mid, pool in self._pools.items()
                  if pool["pending"] and pool["state"] is not None}
        for mid, pool in self._pools.items():
            eng = pool["engine"]
            if pool["pending"]:
                slots = [s for s, _ in pool["pending"]]
                reqs = [r for _, r in pool["pending"]]
                prompts, caps = eng.synth_prompts(reqs, self.rng)
                if pool["state"] is None:
                    pool["state"] = eng.start_chunked(
                        prompts, caps, quant_bits=self._cohort_bits(pool),
                        arena=self.arena if pool["paged"] else None)
                    pool["t"] = 0
                else:
                    pool["state"] = eng.refill_chunked(
                        pool["state"], slots, prompts, caps,
                        t_now=pool["t"], cap_max=clamps[mid])
                pool["resident"].update(zip(slots, reqs))
                pool["pending"].clear()
        self._pending_pages = 0     # reservations became real leases
        for mid, pool in self._pools.items():
            eng = pool["engine"]
            occupied += len(pool["resident"])
            capacity += pool["capacity"]
            if pool["state"] is None:
                continue
            pool["state"] = eng.generate_chunked(pool["state"], k)
            # light poll: the hot path only needs the occupancy view,
            # not the (B, n_max) token buffer
            out, lengths, done, t = eng.poll_chunked(
                pool["state"], with_tokens=self.collect_tokens)
            pool["t"] = t
            caps_h = pool["state"].caps_host
            freed = []
            for slot, r in list(pool["resident"].items()):
                if done[slot] or lengths[slot] >= caps_h[slot]:
                    finished.append((mid, r, int(lengths[slot])))
                    if self.collect_tokens:
                        self.outputs[r.rid] = \
                            np.array(out[slot][:lengths[slot]])
                    del pool["resident"][slot]
                    freed.append(slot)
            if pool["paged"] and freed:
                # release-on-completion: the freed pages are allocatable
                # by ANY cohort at the next admission boundary
                pool["state"] = eng.release_slots(pool["state"], freed)
            if not pool["resident"]:
                if pool["paged"]:
                    eng.release_all(pool["state"])
                pool["state"], pool["t"] = None, 0   # cohort drained
        return finished, occupied / capacity if capacity else 0.0

    def block_usage(self):
        if self.arena is None:
            return super().block_usage()
        bt = self.arena.block_tokens
        live_tokens = 0
        for pool in self._pools.values():
            if pool.get("paged") and pool["state"] is not None:
                eng = pool["engine"]
                live_tokens += len(pool["resident"]) \
                    * (eng.s_max + pool["t"])
        alloc_tokens = self.arena.pages_in_use * bt
        return (self.arena.pages_in_use, self.arena.total_pages,
                live_tokens, alloc_tokens)


class ContinuousRuntime(EpochRuntime):
    """Continuous-batching sibling of the epoch loop (DESIGN.md §2.1).

    Same arrival / aging / viability-drop bookkeeping on the same epoch
    grid, but each epoch is split into ``segments_per_epoch`` chunked
    decode segments and ADMISSION happens at every segment boundary:
    FIFO first-fit over the queue, each candidate gated by
    ``policy.validate()`` on (resident ∪ candidate) — the paper's P1
    feasibility oracle reused as the admission-control contract, so no
    slot refill can violate the constraint set the scheduler enforces at
    epoch boundaries.  On a ``MultiLLMEnv`` the gate is NODE-WIDE: the
    joint resident batch across every hosted cohort is additionally
    re-checked against ``multi_feasible`` (raising
    ``InfeasibleDecisionError`` on a policy whose oracle is only
    per-model feasible), and each freshly started cohort's quantization
    method comes from ``policy.select_quant`` (the PR-2 descent for
    ``quant=auto``), recorded in ``EpochTrace.quants``.  Resident
    requests keep their admission-time waits; ``schedule()`` is never
    called — continuous batching replaces the batch-selection problem
    with per-request admission control.

    Requests are counted served when their generation FINISHES (the
    epoch runtime counts at selection; with its execute-within-the-epoch
    contract the two agree on epoch attribution).  After the last epoch
    the resident cohorts DRAIN to completion (bounded by one cohort
    span), attributed to the final epoch — so for ``warmup_epochs=0``
    conservation holds exactly: ``arrived == served + dropped +
    len(final_queue_rids)``.
    """

    def __init__(self, env: Env, policy: Union[str, SchedulerPolicy],
                 executor: ContinuousExecutor, k: int = 4,
                 segments_per_epoch: Optional[int] = None):
        super().__init__(env, policy)
        self.executor = self.cexec = executor
        self.k = int(k)
        self.segments_per_epoch = segments_per_epoch or max(
            1, math.ceil(executor.tokens_per_epoch() / self.k))

    # -- admission: validate()-gated first-fit -------------------------------

    def _assert_jointly_feasible(self, batches: Dict[Optional[str],
                                                     List[Request]],
                                 quants: Dict[Optional[str], QuantMethod]
                                 ) -> None:
        """Authoritative node-wide re-check on multi-LLM nodes: an
        admission boundary must leave the JOINT resident batch feasible
        under ``multi_feasible`` (shared spectrum, shared memory pool,
        sequential compute slot).  Per-model feasibility does not compose
        across cohorts on shared budgets — a policy whose oracle only
        checks its own model's view cheats the node and is caught here,
        at admission, before anything serves.  Run ONCE per boundary
        (not per candidate): every joint constraint is monotone in batch
        growth, so an infeasible intermediate state cannot become
        feasible again by the end of the loop — same detection at 1/N
        the oracle cost."""
        if not isinstance(self.env, MultiLLMEnv):
            return
        order = getattr(self.policy, "order", "weight")
        if not multi_feasible(self.env, batches, order=order,
                              quants=quants):
            raise InfeasibleDecisionError(
                f"{self.policy.spec}: admission accepted a candidate "
                f"whose joint resident batch fails multi_feasible — "
                f"per-model feasibility does not compose on shared node "
                f"budgets")

    def _try_admit(self, queue: List[Request],
                   trace: EpochTrace) -> List[Request]:
        """Admit queued requests into free slots, FIFO first-fit, each
        gated by the policy's own feasibility oracle on the joint
        resident-plus-candidate batch — evaluated under every active
        cohort's decided quantization method — then re-checked against
        the joint ``multi_feasible`` oracle on multi-LLM nodes.  The
        resident view is built once per boundary and updated
        incrementally as candidates land.

        The first admission into an empty pool STARTS a cohort: the
        policy picks its quantization method (``select_quant``, the
        PR-2 descent for ``quant=auto`` policies) over the queued
        requests targeting that model, the executor pins the cohort to
        it, and the choice is recorded in ``trace.quants``."""
        admitted: List[Request] = []
        cexec = self.cexec
        batches = {m: cexec.resident(m) for m in cexec.pool_ids()}
        # methods the ACTIVE cohorts are being served with (a drained
        # pool's stale method is ignored: its next cohort re-decides)
        quants = {m: q for m in cexec.pool_ids()
                  if batches[m] and (q := cexec.quant_of(m)) is not None}
        fresh_sel: Dict[Optional[str], Optional[QuantMethod]] = {}
        for r in queue:
            mid = r.model_id
            if mid not in batches or not cexec.accepts(mid, r):
                continue
            starting = not batches[mid]
            if starting:
                if mid not in fresh_sel:
                    fresh_sel[mid] = self.policy.select_quant(
                        self.env, mid,
                        [x for x in queue if x.model_id == mid])
                q = fresh_sel[mid]
            else:
                q = quants.get(mid)
            batches[mid].append(r)
            trial = dict(quants)
            if q is not None:
                trial[mid] = q
            if self.policy.validate(self.env, Decision(batches=batches,
                                                       quants=trial)):
                if starting:
                    cexec.set_quant(mid, q)
                    if q is not None:
                        trace.quants[mid] = q.name
                quants = trial
                cexec.place(mid, r)
                admitted.append(r)
            else:
                batches[mid].pop()
        if admitted:
            self._assert_jointly_feasible(batches, quants)
        return admitted

    def _record_blocks(self, counting: bool, m: EpochMetrics,
                       trace: EpochTrace) -> None:
        """Per-segment KV-block accounting (DESIGN.md §2.3): the
        executor's ``block_usage`` snapshot feeds the trace's in-use
        series and the run-level occupancy/fragmentation aggregates."""
        in_use, total, live_tok, alloc_tok = self.cexec.block_usage()
        trace.kv_blocks_in_use.append(in_use)
        trace.kv_blocks_total = total
        if counting:
            m.kv_alloc_tokens += alloc_tok
            m.kv_dead_tokens += max(0, alloc_tok - live_tok)

    def _record_finished(self, finished: Sequence, counting: bool,
                         m: EpochMetrics, trace: EpochTrace) -> None:
        for mid, r, tokens in finished:
            trace.finished_rids.append(r.rid)
            trace.generated_tokens += tokens
            if counting:
                m.served += 1
                m.generated_tokens += tokens
                m.served_by_model[mid] = \
                    m.served_by_model.get(mid, 0) + 1
                name = self.cexec.method_name(mid, self._env_for(r))
                m.served_by_method[name] = \
                    m.served_by_method.get(name, 0) + 1

    def run(self, rate: Optional[float] = None, n_epochs: int = 30,
            seed: int = 0, gen: Optional[RequestGenerator] = None,
            warmup_epochs: int = 1,
            tag_arrivals: Optional[Callable[[List[Request]],
                                            List[Request]]] = None
            ) -> EpochMetrics:
        gen = self._resolve_gen(rate, seed, gen)
        T_E = self.T_E
        n_seg = self.segments_per_epoch
        dt = T_E / n_seg
        self.cexec.bind(self.env)
        m = EpochMetrics(n_epochs=n_epochs, T_E=T_E)
        queue: List[Request] = []
        trace: Optional[EpochTrace] = None

        for e in range(n_epochs + warmup_epochs):
            counting = e >= warmup_epochs
            trace = EpochTrace(epoch=e, arrived=0, dropped=0,
                               selected_rids=[], counted=counting)
            for j in range(n_seg):
                t_seg = e * T_E + j * dt
                # requests that arrived during the previous SEGMENT join
                # here — the epoch loop's boundary rule, at segment grain
                arrivals = gen.within(t_seg - dt, t_seg) if (e or j) else []
                if tag_arrivals is not None:
                    arrivals = tag_arrivals(arrivals)
                trace.arrived += len(arrivals)
                if counting:
                    m.arrived += len(arrivals)
                queue.extend(arrivals)

                queue, n_dropped = self._age_and_drop(queue, t_seg)
                trace.dropped += n_dropped
                if counting:
                    m.dropped += n_dropped
                admitted = self._try_admit(queue, trace)
                if admitted:
                    got = {r.rid for r in admitted}
                    queue = [r for r in queue if r.rid not in got]
                    trace.selected_rids.extend(r.rid for r in admitted)
                    if j > 0:
                        trace.admitted_mid_epoch += len(admitted)
                        if counting:
                            m.admitted_mid_epoch += len(admitted)

                t0 = time.perf_counter()
                finished, occ = self.cexec.step(self.env, self.k)
                trace.wall_s += time.perf_counter() - t0
                trace.segments += 1
                trace.occupancy.append(occ)
                self._record_blocks(counting, m, trace)
                if counting:
                    m.segments += 1
                self._record_finished(finished, counting, m, trace)

            if counting:
                m.batch_sizes.append(len(trace.selected_rids))
                m.wall_s += trace.wall_s
            m.traces.append(trace)

        # drain resident cohorts (bounded: every step makes progress and
        # nothing new is admitted), attributed to the final epoch
        counting = n_epochs > 0
        for _ in range(100_000):
            if self.cexec.idle():
                break
            t0 = time.perf_counter()
            finished, occ = self.cexec.step(self.env, self.k)
            wall = time.perf_counter() - t0
            trace.wall_s += wall
            trace.segments += 1
            trace.occupancy.append(occ)
            self._record_blocks(counting, m, trace)
            if counting:
                m.segments += 1
                m.wall_s += wall
            self._record_finished(finished, counting, m, trace)
        else:
            raise RuntimeError("continuous drain did not converge")

        m.final_queue_rids = [r.rid for r in queue]
        return m
