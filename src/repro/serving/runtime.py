"""EpochRuntime: THE epoch/queue lifecycle loop (paper Fig. 2 + §IV).

Historically the protocol — arrivals join at the epoch boundary, queued
requests age, hopeless requests drop, a scheduler picks a batch, served
requests leave — was hand-rolled three times (analytic sim, real-engine
serving, multi-LLM benchmarks) with drifting semantics.  It now lives
here exactly once, parameterized on two axes:

  * control plane — a ``SchedulerPolicy`` (core/policy.py): what to batch,
    WITH WHICH QUANTIZATION METHOD (``Decision.quants``), and the
    feasibility oracle the runtime re-checks it against;
  * data plane — an ``Executor``: how a decision is carried out.
    ``AnalyticExecutor`` charges cost-model time only (the paper's
    figures); ``EngineExecutor`` runs each batch on real JAX models via
    ``ServingEngine.generate`` — at the decision's precision, through the
    engine's multi-precision weight cache — clamping to engine capacity
    with a feasibility re-check and spill accounting instead of the old
    silent truncation.

The epoch loop records each epoch's decided method per model in its
``EpochTrace.quants`` and aggregates ``EpochMetrics.served_by_method``,
so adaptive-precision runs are auditable epoch by epoch.  It also times
every ``executor.execute`` call (``EpochTrace.wall_s``, aggregated into
``EpochMetrics.wall_s`` / ``tokens_per_s``) — under ``EngineExecutor``
that is the real data plane's measured decode throughput, since
``ServingEngine.generate`` blocks on its single device→host transfer.  (The historical
``simulate`` / ``serve_epochs`` / ``sweep`` shims are gone; drive this
class directly.)
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple, Union

import numpy as np

from repro.core.environment import EdgeEnv
from repro.core.metrics import EpochMetrics, EpochTrace
from repro.core.multi import MultiLLMEnv
from repro.core.policy import Decision, SchedulerPolicy, as_policy
from repro.core.request import Request, RequestGenerator

Env = Union[EdgeEnv, MultiLLMEnv]


def still_viable(env: EdgeEnv, r: Request, now: float) -> bool:
    """Could this queued request still meet its deadline if scheduled at the
    *next* epoch boundary?  Lower bound: comm slots + its lone compute at
    its true prompt length (<= any batched/padded execution).

    The bound is computed under the env's deployed method even when a
    policy selects quant per epoch — it is a drop heuristic, and keeping
    it method-independent keeps fixed- and adaptive-method runs on the
    same queue trajectory for like-for-like comparison."""
    t_w = now - r.arrival
    cm = env.cost_model()
    lone = env.quant.beta * (cm.prefill_flops(r.s, 1)
                             + cm.decode_flops(r.s, [r.n])) / env.C
    return t_w + env.T_U + lone + env.T_D <= r.tau + 1e-12


# ---------------------------------------------------------------------------
# Executors: the data plane behind a scheduling decision
# ---------------------------------------------------------------------------


class Executor:
    """How a scheduling decision is carried out each epoch."""

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        """Clamp a decision to this data plane's capacity.  Returns the
        (possibly reduced) decision plus the spilled requests, which stay
        in the queue for later epochs."""
        return decision, []

    def execute(self, env: Env, decision: Decision) -> int:
        """Run the decision; returns the number of generated tokens."""
        raise NotImplementedError


class AnalyticExecutor(Executor):
    """Cost-model-time execution: nothing runs, latency/memory are charged
    analytically (P1's constraints).  The paper's evaluation path."""

    def execute(self, env: Env, decision: Decision) -> int:
        return 0


class EngineExecutor(Executor):
    """Real data plane: each batch executes on a ``ServingEngine``
    (batched prefill + decode on the JAX model).

    ``engines`` is one engine (single-model node) or a dict keyed by
    ``model_id`` mirroring a MultiLLMEnv's hosted deployments.  Batches
    larger than an engine's static ``batch_capacity`` are clamped and the
    spill is reported to the runtime (re-queued + counted) — the clamped
    batch is re-validated against the policy's own oracle rather than
    trusted silently.

    When a decision carries a quant assignment, each batch executes at
    that method's weight precision via the engine's multi-precision
    weight cache (``ServingEngine.params_for``) — the decided precision
    actually reaches the Pallas dequant-matmul kernel.
    """

    def __init__(self, engines, rng: Optional[np.random.Generator] = None,
                 seed: int = 0):
        if not isinstance(engines, dict):
            engines = {None: engines}
        self.engines = engines
        self.rng = rng if rng is not None else np.random.default_rng(seed)

    def admit(self, env: Env, policy: SchedulerPolicy, decision: Decision
              ) -> Tuple[Decision, List[Request]]:
        spilled: List[Request] = []
        batches = {}
        for mid, batch in decision.batches.items():
            cap = self.engines[mid].batch_capacity
            batches[mid] = batch[:cap]
            spilled.extend(batch[cap:])
        if not spilled:
            return decision, []
        clamped = Decision(batches=batches, stats=decision.stats,
                           quants=decision.quants)
        # Feasibility is monotone under request removal for every shipped
        # policy, but the oracle is the contract — re-check, don't assume.
        assert policy.validate(env, clamped), \
            f"{policy.spec}: capacity-clamped batch failed its own oracle"
        return clamped, spilled

    def execute(self, env: Env, decision: Decision) -> int:
        tokens = 0
        for mid, batch in decision.batches.items():
            if not batch:
                continue
            engine = self.engines[mid]
            prompts, caps = engine.synth_prompts(batch, self.rng)
            q = decision.quants.get(mid)
            result = engine.generate(
                prompts, caps,
                quant_bits=None if q is None else q.weight_bits)
            tokens += int(result.lengths.sum())
        return tokens


# ---------------------------------------------------------------------------
# The one control loop
# ---------------------------------------------------------------------------


class EpochRuntime:
    """Drives the epoch protocol for any (env, policy, executor) triple."""

    def __init__(self, env: Env, policy: Union[str, SchedulerPolicy],
                 executor: Optional[Executor] = None):
        self.env = env
        self.policy = as_policy(policy)
        self.executor = executor or AnalyticExecutor()

    @property
    def T_E(self) -> float:
        return self.env.T_E

    def _env_for(self, r: Request) -> Optional[EdgeEnv]:
        """The single-model constraint view serving this request."""
        if isinstance(self.env, MultiLLMEnv):
            return self.env.env_for(r)
        return self.env

    def run(self, rate: Optional[float] = None, n_epochs: int = 30,
            seed: int = 0, gen: Optional[RequestGenerator] = None,
            warmup_epochs: int = 1,
            tag_arrivals: Optional[Callable[[List[Request]],
                                            List[Request]]] = None
            ) -> EpochMetrics:
        """Run the epoch protocol with Poisson(rate) arrivals.

        The first ``warmup_epochs`` epochs run but are excluded from the
        aggregate metrics (queue fill-up transient).  ``tag_arrivals``
        lets multi-LLM workloads assign each arrival a ``model_id``.
        """
        if gen is None:
            if rate is None:
                raise ValueError("provide either rate= or gen=")
            gen = RequestGenerator(rate=rate, seed=seed,
                                   lengths=(128, 256, 512))
        T_E = self.T_E
        m = EpochMetrics(n_epochs=n_epochs, T_E=T_E)
        queue: List[Request] = []

        for e in range(n_epochs + warmup_epochs):
            t0 = e * T_E
            counting = e >= warmup_epochs
            # requests that arrived during the previous epoch join the queue
            arrivals = gen.within(t0 - T_E, t0) if e else []
            if tag_arrivals is not None:
                arrivals = tag_arrivals(arrivals)
            if counting:
                m.arrived += len(arrivals)
            queue.extend(arrivals)

            # age the queue; drop hopeless (or untargeted) requests
            viable: List[Request] = []
            n_dropped = 0
            for r in queue:
                r.t_w = t0 - r.arrival
                env_r = self._env_for(r)
                if env_r is not None and still_viable(env_r, r, t0):
                    viable.append(r)
                else:
                    n_dropped += 1
                    if counting:
                        m.dropped += 1
            queue = viable

            decision = self.policy.schedule(self.env, queue)
            decision, spilled = self.executor.admit(self.env, self.policy,
                                                    decision)
            # authoritative re-check against the policy's own oracle
            # (schedulers must not cheat)
            assert self.policy.validate(self.env, decision), \
                f"{self.policy.spec} returned an infeasible batch"
            # real executors block on the result (ServingEngine.generate
            # device_gets), so this wall-clock is the data plane's t_A+t_I
            t_exec = time.perf_counter()
            tokens = self.executor.execute(self.env, decision)
            wall_s = time.perf_counter() - t_exec

            sel = decision.selected
            # the method each served model actually ran with this epoch
            quants = {mid: decision.quant_for(mid, self.env).name
                      for mid, batch in decision.batches.items() if batch}
            if counting:
                m.served += len(sel)
                m.batch_sizes.append(len(sel))
                m.nodes_visited += decision.stats.nodes_visited
                m.leaves_checked += decision.stats.leaves_checked
                m.truncated += len(spilled)
                m.generated_tokens += tokens
                m.wall_s += wall_s
                for mid, batch in decision.batches.items():
                    if batch:
                        name = quants[mid]
                        m.served_by_method[name] = \
                            m.served_by_method.get(name, 0) + len(batch)
            m.traces.append(EpochTrace(
                epoch=e, arrived=len(arrivals), dropped=n_dropped,
                selected_rids=[r.rid for r in sel], truncated=len(spilled),
                nodes_visited=decision.stats.nodes_visited,
                generated_tokens=tokens, counted=counting,
                quants=quants, wall_s=wall_s))

            chosen = {r.rid for r in sel}
            queue = [r for r in queue if r.rid not in chosen]
        return m
