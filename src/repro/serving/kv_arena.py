"""Node-wide paged KV arena: block-pool allocator + per-row block tables.

DESIGN.md §2.3.  The continuous path historically gave every cohort a
contiguous (B, s_max + n_max) slab, so KV memory freed by one model's
finished rows was invisible to every other cohort and node-wide occupancy
sat at 0.12–0.19.  The arena virtualizes that memory vLLM-style:

* ONE device-resident pool of fixed ``block_tokens``-slot pages per KV
  precision, shaped ``(L, n_pages, block_tokens, *tail)`` per cache leaf
  (layers stacked so one page id covers all L layers of a row's block);
* a free-list allocator — ``alloc`` leases pages to a cohort row,
  ``free`` returns them the moment the row completes, so any hosted
  cohort can reuse them at the very next admission boundary;
* a :class:`BlockTable` per cohort mapping (row, logical block) to its
  physical page; the paged flash-decode kernel and the gather fallback
  both read K/V through this indirection.

Two pages are RESERVED and never allocated:

* ``ZERO_PAGE`` — all-zero, NEVER written.  Rows refilled mid-cohort at
  step t have a junk gap ``[s_max, s_max + t)`` the slab path fills with
  zero K/V (the paper's s' padding class); their fully-dead gap blocks
  map here so the gap costs no physical pages.  A live row's first write
  block ``(s_max + t) // block_tokens`` is always a real page, so the
  zero page stays zero.
* ``TRASH_PAGE`` — scratch for rows with no lease (empty slots, and
  completed rows after release) AND for every block beyond a row's
  cap-aware lease span.  Dead rows keep stepping through the model
  (exactly like the slab path), so their writes need somewhere to land,
  and a live row that exhausts its cap mid-segment overflows here too;
  duplicate-index scatters into this page are don't-care garbage that
  no live row ever reads — blocks a row will actually need are leased
  (segment-boundary top-up, ``BlockTable.extend_row``) BEFORE the write
  cursor enters them.

Sizing: ``for_engines`` provisions ``shrink`` × the summed slab page
count of the attached engines (+ the reserved pair).  ``shrink < 1`` is
the whole point — block-level reuse serves the same traffic from less
physical memory (benchmarks/paged_vs_slab.py measures exactly this).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

ZERO_PAGE = 0
TRASH_PAGE = 1
N_RESERVED = 2


class ArenaError(RuntimeError):
    """Allocator misuse: double-free, freeing a reserved page, or a page
    id outside the pool.  A REAL exception (not an assert) so the guards
    survive ``python -O`` — CI smokes the arena suite under ``-O`` to
    keep it that way."""


class ArenaExhausted(ArenaError):
    """alloc() asked for more pages than the free list holds — admission
    control must gate on ``free_pages`` so this never fires in the
    runtime (it firing in a test means the gate is broken)."""


class BlockTable:
    """Logical-block → physical-page map for one cohort (B rows × n_b
    logical blocks).  Host array is authoritative; ``device`` is the
    int32 mirror the jitted decode segment reads (re-shipped only when
    rows change — admission/release/top-up boundaries, never
    mid-segment).  ``n_pages`` (when given) bounds every page id written
    through ``set_row``/``extend_row`` — an id the device buffers don't
    have must fail loudly at the table, not as silent garbage gathers."""

    def __init__(self, batch: int, n_blocks: int,
                 n_pages: Optional[int] = None):
        self.host = np.full((batch, n_blocks), TRASH_PAGE, np.int32)
        self.n_pages = n_pages
        self._device: Optional[jax.Array] = None

    @property
    def device(self) -> jax.Array:
        if self._device is None:
            self._device = jax.device_put(self.host)
        return self._device

    def _check(self, pages: np.ndarray) -> None:
        if pages.size and (pages.min() < 0 or (self.n_pages is not None
                                               and pages.max()
                                               >= self.n_pages)):
            raise ArenaError(
                f"page id out of range [0, {self.n_pages}): "
                f"{sorted(set(pages.tolist()))}")

    def set_row(self, slot: int, pages: Sequence[int]) -> None:
        pages = np.asarray(pages, np.int32)
        self._check(pages)
        self.host[slot] = pages
        self._device = None

    def extend_row(self, slot: int, start: int,
                   pages: Sequence[int]) -> None:
        """Map blocks ``[start, start + len(pages))`` of a LIVE row to
        freshly leased pages — the incremental lease top-up (DESIGN.md
        §2.3).  Host-side remap only; the device mirror re-ships lazily,
        so any number of same-boundary extends cost ONE transfer."""
        pages = np.asarray(pages, np.int32)
        self._check(pages)
        self.host[slot, start:start + len(pages)] = pages
        self._device = None

    def clear_row(self, slot: int) -> None:
        """Remap a row entirely to the trash page (dead rows keep
        stepping; their writes become don't-care scatters)."""
        self.host[slot] = TRASH_PAGE
        self._device = None

    def row_leases(self, slot: int) -> List[int]:
        """Real (allocated) pages currently mapped by a row."""
        return [int(p) for p in self.host[slot] if p >= N_RESERVED]


class KVArena:
    """Fixed-size block pool shared by every paged engine on the node."""

    def __init__(self, leaf_specs: Dict[str, Any], n_pages: int,
                 block_tokens: int):
        assert n_pages > N_RESERVED, n_pages
        self.block_tokens = int(block_tokens)
        self.n_pages = int(n_pages)
        self.leaf_specs = dict(leaf_specs)
        # ZERO_PAGE relies on zero-init: zero K/V (and zero scales for
        # the int8 leaves — dequant 0 * 0 == the slab path's zero gap)
        self._buffers = {
            name: jnp.zeros((spec.shape[0], n_pages, block_tokens)
                            + tuple(spec.shape[3:]), spec.dtype)
            for name, spec in leaf_specs.items()}
        # LIFO list (pop order: hot pages stay hot) + membership set, so
        # the double-free guard is O(1) and a REAL check — not an O(n)
        # scan hidden inside an assert that ``python -O`` strips
        self._free: List[int] = list(range(n_pages - 1, N_RESERVED - 1, -1))
        self._free_set = set(self._free)
        self.alloc_peak = 0

    # -- construction --------------------------------------------------------

    @classmethod
    def for_engines(cls, engines, block_tokens: int = 16,
                    shrink: float = 1.0, extra_pages: int = 0) -> "KVArena":
        """Size an arena for the paged-capable engines of a node.

        Page-leaf shapes are derived structurally from each engine's
        ``init_cache`` (batch 1).  Engines must share leaf names, layer
        count, and dtype, and have a ``cache_len`` divisible by
        ``block_tokens`` — the divisibility is what makes the gathered
        paged cache bitwise equal to the slab cache, the invariant the
        equivalence tests pin.  Trailing dims (n_kv heads, d_head,
        scale widths) may DIFFER across cohorts: the pool provisions the
        elementwise max and each engine reads/writes only the leading
        slice of a page's tail, so one free list still serves every
        hosted model (the cross-cohort reuse the arena exists for)."""
        paged = [e for e in _as_list(engines) if e.paged_capable]
        if not paged:
            raise ValueError("no paged-capable engine to size the arena for")
        specs: Optional[Dict[str, Any]] = None
        slab_pages = 0
        for e in paged:
            if e.cache_len % block_tokens:
                raise ValueError(
                    f"cache_len {e.cache_len} not divisible by "
                    f"block_tokens {block_tokens}")
            s = jax.eval_shape(lambda e=e: e.model.init_cache(1, e.cache_len))
            s = {k: v for k, v in s.items()}
            if specs is None:
                specs = s
            else:
                if set(specs) != set(s):
                    raise ValueError("paged engines must share KV leaf names")
                for name, spec in s.items():
                    have = specs[name]
                    if (have.dtype != spec.dtype
                            or len(have.shape) != len(spec.shape)
                            or have.shape[0] != spec.shape[0]):
                        raise ValueError(
                            "paged engines must share KV layer count and "
                            f"dtype (leaf {name!r}: {have.shape} "
                            f"{have.dtype} vs {spec.shape} {spec.dtype})")
                    tail = tuple(max(a, b) for a, b in
                                 zip(have.shape[3:], spec.shape[3:]))
                    specs[name] = jax.ShapeDtypeStruct(
                        have.shape[:3] + tail, have.dtype)
            slab_pages += e.batch_capacity * (e.cache_len // block_tokens)
        n_pages = N_RESERVED + extra_pages \
            + max(1, math.ceil(slab_pages * shrink))
        return cls(specs, n_pages, block_tokens)

    # -- allocator -----------------------------------------------------------

    @property
    def total_pages(self) -> int:
        """Allocatable pages (reserved pair excluded)."""
        return self.n_pages - N_RESERVED

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.total_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Lease ``n`` pages (LIFO — hot pages stay hot).  Raises
        :class:`ArenaExhausted` if the free list is short."""
        if n > len(self._free):
            raise ArenaExhausted(
                f"need {n} pages, {len(self._free)} free of "
                f"{self.total_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(pages)
        self.alloc_peak = max(self.alloc_peak, self.pages_in_use)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return leased pages.  Raises :class:`ArenaError` on a
        double-free, a reserved page, or an id outside the pool —
        real exceptions, because an allocator whose guards vanish under
        ``python -O`` silently grows the free list and later leases
        pages the device buffers don't have."""
        for p in pages:
            p = int(p)
            if p < N_RESERVED:
                raise ArenaError(f"freeing reserved page {p}")
            if p >= self.n_pages:
                raise ArenaError(
                    f"freeing out-of-range page {p} (pool has "
                    f"{self.n_pages} pages)")
            if p in self._free_set:
                raise ArenaError(f"double free of page {p}")
            self._free.append(p)
            self._free_set.add(p)

    # -- device buffers ------------------------------------------------------

    def buffers(self) -> Dict[str, jax.Array]:
        """Current page-buffer handles.  A jitted segment CONSUMES these
        (donation on supporting backends) — always hand the returned
        tree back via ``set_buffers``."""
        return self._buffers

    def set_buffers(self, bufs: Dict[str, jax.Array]) -> None:
        self._buffers = bufs


def _as_list(engines):
    if isinstance(engines, dict):
        return list(engines.values())
    if isinstance(engines, (list, tuple)):
        return list(engines)
    return [engines]
