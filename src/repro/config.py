"""Configuration system for the repro framework.

Frozen dataclasses describing model architectures, input shapes, meshes,
quantization, and serving setups.  Every assigned architecture registers a
``ModelConfig`` via :func:`register_arch`; lookup is by the canonical
(dash-separated) id, e.g. ``get_arch("mixtral-8x22b")``.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # d_ff in ModelConfig is interpreted per-expert when n_experts > 0.


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block parameters (Mamba2 SSD & xLSTM)."""
    d_state: int = 64          # N in Mamba2; per-head state width
    head_dim: int = 64         # SSD head dim (P)
    expand: int = 2            # d_inner = expand * d_model
    chunk: int = 128           # chunk length for the chunked SSD scan
    conv_width: int = 4        # depthwise conv width in Mamba blocks


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8       # every k-th block is an sLSTM block, rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_width: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + periodically applied shared
    attention block (one set of attention weights reused at several depths)."""
    attn_every: int = 6        # apply the shared attention block every k layers
    shared_attn: bool = True   # single shared weight set (Zamba2)


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder."""
    n_enc_layers: int = 4
    n_audio_frames: int = 1500   # encoder sequence length (stub conv frontend)


@dataclass(frozen=True)
class VLMConfig:
    n_img_tokens: int = 256      # patch embeddings emitted by the stub ViT


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 => d_model // n_heads
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"           # silu (swiglu) | gelu | relu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0     # 0 => full attention
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_bits: int = 16           # 8 => int8 KV cache (per-token scales)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    source: str = ""            # citation for the config values
    notes: str = ""

    # ---- derived ---------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """True when decode cost/memory does not grow with full context length
        (SSM / hybrid state, or bounded sliding-window attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs are decoders or enc-dec

    def vocab_padded(self, multiple: int = 256) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def param_count(self) -> int:
        """Total parameter count (all experts counted)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        return _param_count(self, active_only=True)

    def scaled(self, **kw) -> "ModelConfig":
        """Return a reduced/modified copy (used by smoke tests)."""
        return dataclasses.replace(self, **kw)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    dm, dh = cfg.d_model, cfg.d_head
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    V = cfg.vocab

    def attn_params() -> int:
        return dm * (nh * dh) + 2 * dm * (nkv * dh) + (nh * dh) * dm

    def ffn_params(d_ff: int) -> int:
        if cfg.act == "silu":      # gated: w1, w3 up + w2 down
            return 3 * dm * d_ff
        return 2 * dm * d_ff

    if cfg.family == "ssm" and cfg.xlstm is not None:
        # xLSTM: per-block in/out projections + cell weights (kept consistent
        # with the actual init in models/xlstm.py).
        d_in = int(cfg.xlstm.proj_factor_mlstm * dm)
        per_mlstm = 2 * dm * d_in + d_in * dm + 3 * d_in * d_in + 2 * d_in
        d_s = dm
        per_slstm = 4 * dm * d_s + 4 * d_s * d_s + int(cfg.xlstm.proj_factor_slstm * dm) * dm * 2
        n_s = cfg.n_layers // cfg.xlstm.slstm_every
        n_m = cfg.n_layers - n_s
        body = n_m * per_mlstm + n_s * per_slstm
    elif cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm.expand * dm
        nheads = d_inner // cfg.ssm.head_dim
        per_mamba = (dm * (2 * d_inner + 2 * cfg.ssm.d_state + nheads)
                     + d_inner * dm + cfg.ssm.conv_width * (d_inner + 2 * cfg.ssm.d_state)
                     + 2 * nheads)
        if cfg.family == "hybrid" and cfg.hybrid is not None:
            n_attn_sites = cfg.n_layers // cfg.hybrid.attn_every
            attn_sets = 1 if cfg.hybrid.shared_attn else n_attn_sites
            body = cfg.n_layers * per_mamba + attn_sets * (attn_params() + ffn_params(cfg.d_ff))
        else:
            body = cfg.n_layers * per_mamba
    else:
        if cfg.is_moe:
            e = cfg.moe.top_k if active_only else cfg.moe.n_experts
            per_layer = attn_params() + e * ffn_params(cfg.d_ff) + dm * cfg.moe.n_experts
        else:
            per_layer = attn_params() + ffn_params(cfg.d_ff)
        body = cfg.n_layers * per_layer
        if cfg.family == "audio" and cfg.encdec is not None:
            enc_per = attn_params() + ffn_params(cfg.d_ff)
            dec_cross = attn_params()
            body = (cfg.encdec.n_enc_layers * enc_per
                    + cfg.n_layers * (per_layer + dec_cross))
    embed = V * dm * (1 if cfg.tie_embeddings else 2)
    return body + embed


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / hardware
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e chip constants used by the roofline and the serving cost model."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16 * 2**30    # per chip


V5E = HardwareSpec()


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantConfig:
    """Post-training quantization description (paper §II-B.3).

    ``alpha`` scales memory, ``beta`` scales compute time, ``dppl`` is the
    perplexity differential (per model, from offline calibration — the paper's
    Table II values are the defaults in ``core/quantization.py``).
    """
    name: str = "W16A16"
    weight_bits: int = 16
    act_bits: int = 16
    method: str = "none"       # none | gptq | zq-local | rtn


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_ARCH_REGISTRY: Dict[str, ModelConfig] = {}
_ASSIGNED_ARCHS = (
    "xlstm-1.3b", "mistral-large-123b", "internvl2-26b", "olmo-1b",
    "whisper-tiny", "mixtral-8x22b", "deepseek-coder-33b", "zamba2-7b",
    "granite-moe-1b-a400m", "qwen3-1.7b",
)
_PAPER_ARCHS = ("bloom-3b", "bloom-7b1", "opt-13b")
_CONFIG_MODULES = [a.replace("-", "_").replace(".", "_") for a in
                   _ASSIGNED_ARCHS + _PAPER_ARCHS]


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _ARCH_REGISTRY[cfg.arch_id] = cfg
    return cfg


def _ensure_loaded() -> None:
    if len(_ARCH_REGISTRY) >= len(_CONFIG_MODULES):
        return
    for mod in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _ARCH_REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_ARCH_REGISTRY)}")
    return _ARCH_REGISTRY[arch_id]


def list_archs(assigned_only: bool = False) -> Tuple[str, ...]:
    _ensure_loaded()
    if assigned_only:
        return _ASSIGNED_ARCHS
    return tuple(sorted(_ARCH_REGISTRY))


def assigned_archs() -> Tuple[str, ...]:
    return _ASSIGNED_ARCHS


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the 4 assigned input shapes run for this arch.

    long_500k requires sub-quadratic decode (SSM/hybrid state or sliding
    window); pure full-attention archs skip it (DESIGN.md §4).
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return tuple(out)
