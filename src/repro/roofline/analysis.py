"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = FLOPs / (chips x 197 TF/s bf16)
  memory     = HBM bytes / (chips x 819 GB/s)
  collective = collective bytes / (chips x 50 GB/s/link ICI)

Two sources are recorded for every term:

* **HLO-reported** — ``compiled.cost_analysis()`` and raw HLO-text
  collective parsing.  CAVEAT: XLA costs a ``while`` body ONCE, so for
  scan-over-layers programs these undercount by ~n_layers.  The collective
  parser fixes this (it walks while bodies and multiplies by trip count);
  flops/bytes keep the raw value as a cross-check only.
* **Analytic** — the paper's own cost model (core/costmodel.py) evaluated
  at the (arch x shape): trusted for scale, used for the headline terms
  and the bottleneck call.

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (forward);
useful_compute_ratio = MODEL_FLOPS / analytic_total_flops (<= 1; the gap
is attention reads, recompute and padding).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

from repro.config import HardwareSpec, ModelConfig, ShapeConfig, V5E
from repro.core.costmodel import CostModel

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of 'bf16[2,3]' / tuple '(f32[8], f32[8])' strings."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# Loop-aware collective parsing
# ---------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_LINE_RE = re.compile(
    r"=\s*((?:[a-z0-9]+\[[^\]]*\])(?:,?\s*[a-z0-9]+\[[^\]]*\])*|\([^()]*\))\s*"
    r"(" + "|".join(_COLLECTIVE_KINDS) + r")(?:-start)?\(")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Map computation name -> body text (brace-delimited blocks)."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo.splitlines():
        if cur_name is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur_name, cur_lines, depth = m.group(1), [line], 1
        else:
            cur_lines.append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
    return comps


def _trip_count(cond_text: str) -> int:
    """Largest integer constant in a while condition ~ the trip count."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Collective bytes by kind, loop-aware: collectives inside a while
    body are multiplied by the loop's trip count (XLA costs bodies once).
    Bytes = output-shape volume per collective (the tensor the ICI must
    deliver per device participation).
    """
    comps = _split_computations(hlo_text)

    def direct(text: str) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for m in _COLL_LINE_RE.finditer(text):
            out[m.group(2)] = out.get(m.group(2), 0.0) \
                + _shape_bytes(m.group(1))
        return out

    memo: Dict[str, Dict[str, float]] = {}

    def total_of(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if depth > 16 or name not in comps:
            return {}
        text = comps[name]
        out = direct(text)
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            sub = total_of(body, depth + 1)
            for k, v in sub.items():
                out[k] = out.get(k, 0.0) + v * trips
        memo[name] = out
        return out

    # entry computation: the one containing ENTRY, else sum top-level text
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    out: Dict[str, float] = {}
    if entry and entry in comps:
        out = dict(total_of(entry))
    else:   # fallback: flat parse, no loop scaling
        out = direct(hlo_text)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# Analytic terms (the paper's cost model at the arch x shape)
# ---------------------------------------------------------------------------


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig
                   ) -> Tuple[float, float]:
    """(total FLOPs, total HBM bytes) for one step of this shape."""
    cm = CostModel(cfg)
    B, S = shape.global_batch, shape.seq_len
    W = cm.weight_bytes()                      # bf16 weight bytes
    act = 2.0 * cfg.d_model * cfg.n_layers     # bytes/token residual traffic
    kv_scale = cfg.kv_bits / 16.0              # int8 KV halves cache bytes
    if shape.kind == "train":
        fwd = cm.prefill_flops(S, B)
        flops = 3.0 * fwd                      # fwd + 2x bwd
        bytes_ = 3.0 * (W + 8.0 * act * B * S) + 8.0 * W   # + AdamW f32 I/O
    elif shape.kind == "prefill":
        flops = cm.prefill_flops(S, B)
        bytes_ = W + kv_scale * cm.kv_bytes_prefill(S, B) \
            + 8.0 * act * B * S
    else:   # decode: ONE token against an S-token cache
        flops = B * cm.decode_flops(S, [2])    # 1 autoregressive iteration
        bytes_ = W + kv_scale * cm.kv_bytes_prefill(S, B) + 8.0 * act * B
    return flops, bytes_


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (forward-only), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   chips: int, hw: HardwareSpec = V5E) -> Dict[str, float]:
    """All three terms in seconds (aggregate work / aggregate capability)."""
    return {
        "t_compute": flops / (chips * hw.peak_flops),
        "t_memory": bytes_ / (chips * hw.hbm_bw),
        "t_collective": coll_bytes / (chips * hw.ici_bw),
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("t_compute", "t_memory", "t_collective"),
               key=lambda k: terms[k])


def analyze_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    lowered, compiled,
                    donated_frac: float = 0.0) -> Dict[str, Any]:
    """Full §Roofline record for one lowered+compiled combination.

    ``donated_frac`` — fraction of argument bytes aliased to outputs by
    buffer donation (CPU AOT analysis does not apply donation, the TPU
    runtime does; we subtract the aliased output bytes to report the
    deployable footprint).
    """
    chips = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returns [dict]
        cost = cost[0]
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))

    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    per_dev = arg_b + out_b + tmp_b - min(donated_frac * arg_b, out_b)

    try:
        hlo_text = compiled.as_text()
    except Exception:
        hlo_text = lowered.as_text()
    coll = collective_bytes(hlo_text)

    a_flops, a_bytes = analytic_costs(cfg, shape)
    terms = roofline_terms(a_flops, a_bytes, coll["total"], chips)
    mf = model_flops(cfg, shape)
    return {
        "chips": chips,
        "analytic_flops": a_flops,
        "analytic_bytes": a_bytes,
        "hlo_flops": hlo_flops,              # cross-check (loop bodies x1)
        "hlo_bytes": hlo_bytes,
        "collective_bytes": coll["total"],   # loop-aware
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "bytes_per_device": per_dev,
        "arg_bytes": arg_b, "out_bytes": out_b, "temp_bytes": tmp_b,
        "fits": per_dev <= V5E.hbm_bytes,
        **terms,
        "bottleneck": dominant_term(terms),
        "model_flops": mf,
        "useful_compute_ratio": mf / a_flops if a_flops else 0.0,
    }
