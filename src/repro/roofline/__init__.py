from repro.roofline.analysis import (analyze_lowered, collective_bytes,
                                     roofline_terms)

__all__ = ["analyze_lowered", "collective_bytes", "roofline_terms"]
