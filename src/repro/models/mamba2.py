"""Mamba2 (SSD) block — TPU-native chunked implementation.

The selective-state-space recurrence is computed with the chunked SSD
algorithm (Dao & Gu, 2024): the sequence is split into chunks of length Q;
within-chunk interactions are dense matmuls (MXU-friendly), across-chunk
state is carried by a short ``lax.scan`` over chunks.  This is the TPU
adaptation called out in DESIGN.md §3 — a step-by-step recurrent scan would
serialize 32k+ tiny matmuls, while the chunked form is matmul-bound.

``ssd_reference`` is the O(T) naive scan oracle used by property tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.utils.sharding import constrain

Params = Dict[str, Any]


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state N)."""
    d_inner = cfg.ssm.expand * cfg.d_model
    P = cfg.ssm.head_dim
    return d_inner, d_inner // P, P, cfg.ssm.d_state


def conv_channels(cfg: ModelConfig) -> int:
    d_inner, _, _, N = dims(cfg)
    return d_inner + 2 * N          # x, B, C share the causal conv


def init_block(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    dm = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * N + H          # z, x, B, C, dt
    return {
        "in_proj": common.dense_init(ks[0], (dm, d_proj), 0, dtype),
        "conv_w": common.dense_init(ks[1], (cfg.ssm.conv_width,
                                            conv_channels(cfg)), 0, dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": common.dense_init(ks[2], (d_inner, dm), 0, dtype),
        "norm": common.make_norm_params(cfg, ks[3], dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, H, P, N = dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(w: jax.Array, xBC: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, width K.  xBC: (B, T, C); state: (B, K-1, C)
    carries the last K-1 inputs for streaming decode.
    Returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    xpad = jnp.concatenate([state, xBC], axis=1)
    out = sum(xpad[:, i:i + xBC.shape[1]] * w[i][None, None]
              for i in range(K))
    new_state = xpad[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(out), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) log-decays -> (..., Q, Q) with [l, s] = sum_{s<j<=l} a_j,
    -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: jax.Array | None = None):
    """Chunked SSD.

    x: (B, T, H, P); dt: (B, T, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, T, N).  Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    Bb, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    T0 = T
    if T % Q:
        # pad with identity steps (dt=0 => decay=1, zero input): state is
        # untouched and the padded outputs are sliced off below.
        pad = Q - T % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        T = T + pad
    nc = T // Q

    a = dt * A[None, None]                       # (B,T,H) log-decay
    xdt = x * dt[..., None]                      # input * step
    # reshape into chunks
    ac = a.reshape(Bb, nc, Q, H).transpose(0, 1, 3, 2)       # (B,nc,H,Q)
    xc = xdt.reshape(Bb, nc, Q, H, P)
    Bc = Bm.reshape(Bb, nc, Q, N)
    Cc = Cm.reshape(Bb, nc, Q, N)

    L = jnp.exp(_segsum(ac))                                  # (B,nc,H,Q,Q)
    # intra-chunk (diagonal block) output
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp",
                        Cc.astype(jnp.float32), Bc.astype(jnp.float32),
                        L, xc.astype(jnp.float32))
    # per-chunk injected state
    a_cum = jnp.cumsum(ac, axis=-1)                           # (B,nc,H,Q)
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)           # (B,nc,H,Q)
    chunk_states = jnp.einsum("bcsn,bchs,bcshp->bchpn",
                              Bc.astype(jnp.float32), decay_to_end,
                              xc.astype(jnp.float32))         # (B,nc,H,P,N)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (B,nc,H)

    def scan_body(state, inp):
        st_c, dec_c = inp                                     # (B,H,P,N),(B,H)
        out_state = state                                     # state BEFORE chunk
        new_state = state * dec_c[..., None, None] + st_c
        return new_state, out_state

    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_body, init_state.astype(jnp.float32),
        (chunk_states.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,nc,H,P,N)

    # inter-chunk contribution
    state_decay = jnp.exp(a_cum)                              # (B,nc,H,Q)
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp",
                       Cc.astype(jnp.float32), state_decay, prev_states)
    y = (y_diag + y_off).reshape(Bb, T, H, P)[:, :T0]
    return y.astype(x.dtype), final_state


def ssd_reference(x, dt, A, Bm, Cm, init_state=None):
    """Naive per-step recurrence oracle (float32)."""
    Bb, T, H, P = x.shape
    N = Bm.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)                              # (B,H)
        state = state * decay[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


def block_forward(cfg: ModelConfig, p: Params, u: jax.Array,
                  collect_state: bool = False):
    """Full-sequence Mamba2 block (pre-norm, residual outside).

    u: (B, T, D).  Returns (out (B,T,D), state | None) where state =
    {"ssm": (B,H,P,N), "conv": (B,K-1,C)} at the end of the sequence.
    """
    d_inner, H, P, N = dims(cfg)
    B, T, _ = u.shape
    proj = u @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, conv_state = _causal_conv(p["conv_w"], xBC)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = constrain(x.reshape(B, T, H, P), "batch", None, "model", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm.chunk)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, T, d_inner)
    y = common.apply_norm("rmsnorm", p["gate_norm"],
                          y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    out = y @ p["out_proj"]
    out = constrain(out, "batch", None, None)
    state = {"ssm": final, "conv": conv_state} if collect_state else None
    return out, state


def block_decode(cfg: ModelConfig, p: Params, u: jax.Array, state):
    """Single-token step.  u: (B, 1, D); state per block_forward."""
    d_inner, H, P, N = dims(cfg)
    B = u.shape[0]
    proj = u @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, conv_state = _causal_conv(p["conv_w"], xBC, state["conv"])
    x, Bm, Cm = jnp.split(xBC[:, 0], [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B, H, P).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A[None])                              # (B,H)
    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", x * dt1[..., None], Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm.astype(jnp.float32))
    y = y + x * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = common.apply_norm("rmsnorm", p["gate_norm"],
                          y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    out = y @ p["out_proj"]
    return constrain(out, "batch", None, None), {"ssm": ssm, "conv": conv_state}


def state_specs(cfg: ModelConfig, batch: int):
    d_inner, H, P, N = dims(cfg)
    K = cfg.ssm.conv_width
    return {"ssm": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, conv_channels(cfg)),
                              jnp.dtype(cfg.dtype))}
