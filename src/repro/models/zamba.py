"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block applied
every ``attn_every`` layers (one weight set reused at all sites).

Layer layout for n_layers=81, attn_every=6:
  13 groups of [6 mamba layers + shared attn+FFN block] + 3 tail mamba layers.

The shared attention uses a 4096 sliding window (DESIGN.md §4): Zamba2's
global memory is carried by the SSM state, so windowing the shared-attn KV
keeps decode memory O(1) in context length and makes long_500k admissible.

Cache pytree:
  main_ssm  (G, K, B, H, P, N)   mamba states (group-major)
  main_conv (G, K, B, cw-1, C)
  tail_ssm  (Tl, B, H, P, N), tail_conv (Tl, B, cw-1, C)
  attn_k/v  (G, B, W, nkv, dh)   shared-attn slot caches per site
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import common, mamba2
from repro.models.api import Model, cross_entropy
from repro.utils.remat import maybe_remat
from repro.utils.sharding import constrain

Params = Dict[str, Any]

ATTN_WINDOW = 4096


def _dtype(cfg): return jnp.dtype(cfg.dtype)


def _layout(cfg: ModelConfig):
    K = cfg.hybrid.attn_every
    G = cfg.n_layers // K
    tail = cfg.n_layers - G * K
    return G, K, tail


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    G, K, tail = _layout(cfg)
    ks = jax.random.split(key, 7)
    Vp = cfg.vocab_padded()

    mk = jax.random.split(ks[0], G * K)
    main_keys = mk.reshape((G, K) + mk.shape[1:])
    main = jax.vmap(jax.vmap(lambda k: mamba2.init_block(cfg, k, dt)))(main_keys)
    tail_p = jax.vmap(lambda k: mamba2.init_block(cfg, k, dt))(
        jax.random.split(ks[1], max(tail, 1)))

    ka, kf, kn = jax.random.split(ks[2], 3)
    shared = {"attn": common.make_attn_params(cfg, ka, dt),
              "ffn": common.make_ffn_params(cfg, kf, dt),
              "norm1": common.make_norm_params(cfg, kn, dt),
              "norm2": common.make_norm_params(cfg, kn, dt)}

    p = {"embed": common.embed_init(ks[3], (Vp, cfg.d_model), dt),
         "main": main, "shared": shared,
         "final_norm": common.make_norm_params(cfg, ks[4], dt),
         "lm_head": common.dense_init(ks[5], (cfg.d_model, Vp), 0, dt)}
    if tail:
        p["tail"] = tail_p
    return p


def _shared_attn_fwd(cfg: ModelConfig, sp: Params, x: jax.Array,
                     positions: jax.Array, W: int, collect: bool):
    """Shared attention + FFN block (full-sequence)."""
    B, S, _ = x.shape
    h = common.apply_norm(cfg.norm, sp["norm1"], x)
    q, k, v = common.qkv_proj(sp["attn"], cfg, h, positions)
    att = common.chunked_causal_attention(q, k, v, ATTN_WINDOW)
    att = att.reshape(B, S, cfg.n_heads * cfg.d_head) @ sp["attn"]["wo"]
    x = x + constrain(att, "batch", None, None)
    h = common.apply_norm(cfg.norm, sp["norm2"], x)
    x = common.seq_shard(x + common.ffn_apply(sp["ffn"], cfg, h))
    cache = common.prefill_cache_from_kv(k, v, W) if collect else None
    return x, cache


def _run_stack(cfg: ModelConfig, params: Params, x: jax.Array,
               collect: bool, W: int = 0):
    """Shared full-sequence pass for forward/prefill."""
    G, K, tail = _layout(cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def mamba_layer(x, lp):
        h = common.apply_norm(cfg.norm, lp["norm"], x)
        out, st = mamba2.block_forward(cfg, lp, h, collect_state=collect)
        return common.seq_shard(x + out), st

    def group(x, gp):
        x, states = jax.lax.scan(maybe_remat(mamba_layer), x, gp)
        x, kvcache = _shared_attn_fwd(cfg, params["shared"], x, positions,
                                      W, collect)
        return x, (states, kvcache)

    x, (main_states, kvcaches) = jax.lax.scan(maybe_remat(group), x,
                                               params["main"])
    tail_states = None
    if tail:
        x, tail_states = jax.lax.scan(mamba_layer, x, params["tail"])
    x = common.apply_norm(cfg.norm, params["final_norm"], x)

    cache = None
    if collect:
        cache = {"main_ssm": main_states["ssm"],
                 "main_conv": main_states["conv"],
                 "attn_k": kvcaches[0], "attn_v": kvcaches[1]}
        if tail:
            cache["tail_ssm"] = tail_states["ssm"]
            cache["tail_conv"] = tail_states["conv"]
    return x, cache


def forward(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    x = constrain(x, "batch", None, None)
    x, _ = _run_stack(cfg, params, x, collect=False)
    return x @ params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, batch):
    logits = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab,
                         batch.get("loss_mask"))
    return loss, {"loss": loss}


def prefill(cfg: ModelConfig, params: Params, batch, cache_len: int = 0):
    x = params["embed"][batch["tokens"]]
    x = constrain(x, "batch", None, None)
    S = x.shape[1]
    W = min(cache_len or S, ATTN_WINDOW)
    x, cache = _run_stack(cfg, params, x, collect=True, W=W)
    logits = (x[:, -1:] @ params["lm_head"])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
                pos: jax.Array):
    G, K, tail = _layout(cfg)
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)

    def mamba_layer(x, inputs):
        lp, st = inputs
        h = common.apply_norm(cfg.norm, lp["norm"], x)
        out, st = mamba2.block_decode(cfg, lp, h, st)
        return x + out, st

    def group(x, inputs):
        gp, g_ssm, g_conv, ck, cv = inputs
        x, states = jax.lax.scan(
            mamba_layer, x, (gp, {"ssm": g_ssm, "conv": g_conv}))
        sp = params["shared"]
        h = common.apply_norm(cfg.norm, sp["norm1"], x)
        att, ck, cv = common.decode_attention(sp["attn"], cfg, h, ck, cv, pos)
        x = x + att
        h = common.apply_norm(cfg.norm, sp["norm2"], x)
        x = x + common.ffn_apply(sp["ffn"], cfg, h)
        return x, (states, ck, cv)

    x, (main_states, new_k, new_v) = jax.lax.scan(
        group, x, (params["main"], cache["main_ssm"], cache["main_conv"],
                   cache["attn_k"], cache["attn_v"]))
    new_cache = {"main_ssm": main_states["ssm"],
                 "main_conv": main_states["conv"],
                 "attn_k": new_k, "attn_v": new_v}
    if tail:
        x, tail_states = jax.lax.scan(
            mamba_layer, x, (params["tail"],
                             {"ssm": cache["tail_ssm"],
                              "conv": cache["tail_conv"]}))
        new_cache["tail_ssm"] = tail_states["ssm"]
        new_cache["tail_conv"] = tail_states["conv"]
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    G, K, tail = _layout(cfg)
    dt = _dtype(cfg)
    d_inner, H, P, N = mamba2.dims(cfg)
    cw, C = cfg.ssm.conv_width, mamba2.conv_channels(cfg)
    W = min(cache_len, ATTN_WINDOW)
    kv = (G, batch, W, cfg.n_kv_heads, cfg.d_head)
    cache = {
        "main_ssm": jnp.zeros((G, K, batch, H, P, N), jnp.float32),
        "main_conv": jnp.zeros((G, K, batch, cw - 1, C), dt),
        "attn_k": jnp.zeros(kv, dt), "attn_v": jnp.zeros(kv, dt),
    }
    if tail:
        cache["tail_ssm"] = jnp.zeros((tail, batch, H, P, N), jnp.float32)
        cache["tail_conv"] = jnp.zeros((tail, batch, cw - 1, C), dt)
    return cache


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    return {"tokens": sds((B, 1), jnp.int32)}


def make_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=lambda p, b: forward(cfg, p, b),
        loss_fn=functools.partial(loss_fn, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
