"""Model API: a uniform functional interface over all architecture families.

Every family module builds a :class:`Model` whose members are plain
functions (jit/pjit-able, scan-over-layers inside).  ``build_model`` is the
single entry point used by the launcher, serving engine, tests and dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig

Params = Any
Cache = Any
Batch = Dict[str, jax.Array]


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    forward: Callable[[Params, Batch], jax.Array]        # logits (B,S,Vp)
    loss_fn: Callable[[Params, Batch], Any]              # (loss, metrics)
    prefill: Callable[[Params, Batch], Any]              # (last logits, cache)
    decode_step: Callable[[Params, Cache, jax.Array, jax.Array], Any]
    init_cache: Callable[[int, int], Cache]              # (batch, cache_len)
    input_specs: Callable[[ShapeConfig], Batch]          # ShapeDtypeStructs
    # paged-KV decode (DESIGN.md §2.3): (params, pages, table, tokens,
    # pos) -> (logits, new_pages); None for families without a slot-cache
    # layout the block arena can virtualize (recurrent state, SWA).
    decode_step_paged: Any = None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer
        return transformer.make_model(cfg)
    if cfg.family == "audio":
        from repro.models import whisper
        return whisper.make_model(cfg)
    if cfg.family == "ssm":
        from repro.models import xlstm
        return xlstm.make_model(cfg)
    if cfg.family == "hybrid":
        from repro.models import zamba
        return zamba.make_model(cfg)
    raise ValueError(f"no model for family {cfg.family!r}")


def token_specs(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int,
                  mask: jax.Array | None = None):
    """Mean CE over valid tokens; logits (B,S,Vp) with Vp >= vocab (padded
    vocab columns masked out)."""
    logits = logits.astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp > vocab:
        pad = jnp.arange(Vp) >= vocab
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction, NOT take_along_axis: gathering along a
    # vocab-parallel dim would force GSPMD to all-gather the full logits
    onehot = jax.nn.one_hot(labels, Vp, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
