"""Model zoo: pure-JAX decoder stacks for every assigned architecture."""
from repro.models.api import Model, build_model  # noqa: F401
