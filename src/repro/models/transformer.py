"""Generic decoder-only transformer covering the dense, MoE and VLM families.

* scan-over-layers (stacked layer params) so HLO size is O(1) in depth;
* GQA attention with optional sliding window / qk-norm;
* MoE FFN (top-k capacity dispatch) when ``cfg.is_moe``;
* VLM: the stub vision frontend supplies patch embeddings that are prepended
  to the text embeddings (deliverable carve-out, DESIGN.md §4).

Cache layout for decode: k/v slot caches (L, B, W, nkv, dh) where
W = sliding window (if any) else full context capacity.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import common
from repro.models.api import Model, cross_entropy
from repro.utils.remat import maybe_remat
from repro.utils.sharding import constrain

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    k_embed, k_layers, k_final, k_head = jax.random.split(key, 4)
    Vp = cfg.vocab_padded()

    def layer_init(lkey):
        ka, kf, kn = jax.random.split(lkey, 3)
        p = {"attn": common.make_attn_params(cfg, ka, dt),
             "norm1": common.make_norm_params(cfg, kn, dt),
             "norm2": common.make_norm_params(cfg, kn, dt)}
        if cfg.is_moe:
            p["moe"] = common.make_moe_params(cfg, kf, dt)
        else:
            p["ffn"] = common.make_ffn_params(cfg, kf, dt)
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(layer_init)(layer_keys)

    params = {
        "embed": common.embed_init(k_embed, (Vp, cfg.d_model), dt),
        "layers": layers,
        "final_norm": common.make_norm_params(cfg, k_final, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(k_head, (cfg.d_model, Vp), 0, dt)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, lp: Params, x: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single layer; returns (x, aux_loss)."""
    h = common.apply_norm(cfg.norm, lp["norm1"], x)
    x = x + common.attention_block(lp["attn"], cfg, h, positions,
                                   window=cfg.sliding_window)
    h = common.apply_norm(cfg.norm, lp["norm2"], x)
    if cfg.is_moe:
        out, aux = common.moe_apply(lp["moe"], cfg, h)
    else:
        out, aux = common.ffn_apply(lp["ffn"], cfg, h), jnp.zeros((), jnp.float32)
    return common.seq_shard(x + out), aux


def _embed_inputs(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    tok = batch["tokens"]
    x = common.maybe_dequant(params["embed"])[tok]
    if cfg.family == "vlm":
        # stub ViT frontend output, already projected to d_model
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    return constrain(x, "batch", None, None)


def _unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ common.maybe_dequant(params["embed"]).T
    else:
        logits = common.mm(x, params["lm_head"])
    return logits


def forward(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_fwd(cfg, lp, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(maybe_remat(body),
                               (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch):
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.family == "vlm":
        # image positions carry no LM loss
        n_img = cfg.vlm.n_img_tokens
        logits = logits[:, n_img:]
    loss = cross_entropy(logits, labels, cfg.vocab, mask)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def cache_capacity(cfg: ModelConfig, context_len: int) -> int:
    return min(context_len, cfg.sliding_window) if cfg.sliding_window \
        else context_len


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    W = cache_capacity(cfg, cache_len)
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_bits == 8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(shape[:-1], jnp.float32),
                "vs": jnp.ones(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, _dtype(cfg)),
            "v": jnp.zeros(shape, _dtype(cfg))}


def prefill(cfg: ModelConfig, params: Params, batch, cache_len: int = 0):
    """Run the prompt through the stack; return (last-token logits, cache).

    ``cache_len`` sets decode cache capacity (0 => prompt length).
    """
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    W = cache_capacity(cfg, cache_len or S)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        q, k, v = common.qkv_proj(lp["attn"], cfg, h, positions)
        att = common.chunked_causal_attention(q, k, v, cfg.sliding_window)
        att = common.mm(att.reshape(B, S, cfg.n_heads * cfg.d_head), lp["attn"]["wo"])
        x = x + constrain(att, "batch", None, None)
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        if cfg.is_moe:
            out, _ = common.moe_apply(lp["moe"], cfg, h)
        else:
            out = common.ffn_apply(lp["ffn"], cfg, h)
        if cfg.kv_bits == 8:
            kq, ks = common.quantize_kv(k)
            vq, vs = common.quantize_kv(v)
            ck, cv = common.prefill_cache_from_kv(kq, vq, W)
            cks, cvs = common.prefill_cache_from_kv(ks[..., None],
                                                    vs[..., None], W)
            layer_cache = {"k": ck, "v": cv,
                           "ks": cks[..., 0], "vs": cvs[..., 0]}
        else:
            ck, cv = common.prefill_cache_from_kv(k, v, W)
            layer_cache = {"k": ck, "v": cv}
        return common.seq_shard(x + out), layer_cache

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = common.apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
                pos: jax.Array, use_kernel: bool = False):
    """One decode iteration.  tokens: (B, 1) int32; pos: scalar int32 giving
    the position of this token (cache holds positions < pos).
    ``use_kernel`` routes attention through the Pallas decode kernels
    (fused quantized flavor when the weights are int8 QTensors)."""
    x = common.maybe_dequant(params["embed"])[tokens]
    x = constrain(x, "batch", None, None)

    def body(x, inputs):
        lp, layer_cache = inputs
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        att, layer_cache = common.decode_attention_cache(
            lp["attn"], cfg, h, layer_cache, pos, use_kernel)
        x = x + att
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        if cfg.is_moe:
            out, _ = common.moe_apply(lp["moe"], cfg, h)
        else:
            out = common.ffn_apply(lp["ffn"], cfg, h)
        return x + out, layer_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, new_cache


def decode_step_paged(cfg: ModelConfig, params: Params, pages, table,
                      tokens: jax.Array, pos: jax.Array,
                      use_kernel: bool = False):
    """One decode iteration over the PAGED cache (DESIGN.md §2.3).

    ``pages``: arena leaves stacked over layers — {"k","v"} of shape
    (L, P, block_tokens, nkv, dh) (+ scale leaves when kv_bits == 8);
    ``table``: (B, n_b) int32 block table, shared by every layer (one
    allocation covers all L layers of a row's block).  Scans layers over
    axis 0 of both params and pages; the table is a scan-invariant
    closure.  Returns (logits, new_pages)."""
    x = common.maybe_dequant(params["embed"])[tokens]
    x = constrain(x, "batch", None, None)

    def body(x, inputs):
        lp, layer_pages = inputs
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        att, layer_pages = common.decode_attention_paged(
            lp["attn"], cfg, h, layer_pages, table, pos, use_kernel)
        x = x + att
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        if cfg.is_moe:
            out, _ = common.moe_apply(lp["moe"], cfg, h)
        else:
            out = common.ffn_apply(lp["ffn"], cfg, h)
        return x + out, layer_pages

    x, new_pages = jax.lax.scan(body, x, (params["layers"], pages))
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, new_pages


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for the dry-run; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        n_text = S - (cfg.vlm.n_img_tokens if cfg.family == "vlm" else 0)
        batch = {"tokens": sds((B, n_text), jnp.int32),
                 "labels": sds((B, n_text), jnp.int32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((B, cfg.vlm.n_img_tokens, cfg.d_model),
                                        _dtype(cfg))
        return batch
    if shape.kind == "prefill":
        n_text = S - (cfg.vlm.n_img_tokens if cfg.family == "vlm" else 0)
        batch = {"tokens": sds((B, n_text), jnp.int32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((B, cfg.vlm.n_img_tokens, cfg.d_model),
                                        _dtype(cfg))
        return batch
    # decode: one new token against a cache of length S
    return {"tokens": sds((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Model factory
# ---------------------------------------------------------------------------


def make_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=lambda p, b: forward(cfg, p, b)[0],
        loss_fn=functools.partial(loss_fn, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        decode_step_paged=functools.partial(decode_step_paged, cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
