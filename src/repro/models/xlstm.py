"""xLSTM (sLSTM + mLSTM blocks) — arXiv:2405.04517.

* mLSTM: matrix-memory linear attention with exponential input gates and
  sigmoid forget gates.  Prefill/training uses a CHUNKWISE form (the TPU
  adaptation, DESIGN.md §3): within-chunk quadratic matmuls + a short scan
  carrying the stabilized state (C_hat, n_hat, m) across chunks — O(T·Q)
  instead of O(T²), matmul-bound on the MXU.  ``mlstm_reference`` is the
  naive O(T) recurrent oracle for property tests.
* sLSTM: scalar-memory recurrent cell with per-head block-diagonal recurrent
  weights; inherently sequential => lax.scan over time.
* Block layout: every ``slstm_every``-th block is an sLSTM block, the rest
  are mLSTM (grouped scan, one group = (slstm_every-1) mLSTM + 1 sLSTM).

Decode state is O(1) in context length => long_500k applies.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import common
from repro.models.api import Model, cross_entropy
from repro.models.mamba2 import _causal_conv
from repro.utils.remat import maybe_remat, remat_enabled
from repro.utils.sharding import constrain

Params = Dict[str, Any]

NEG = -1e30


def _dtype(cfg): return jnp.dtype(cfg.dtype)


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    nh = cfg.n_heads
    return d_in, nh, d_in // nh


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key, dt) -> Params:
    dm = cfg.d_model
    d_in, nh, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": common.make_norm_params(cfg, ks[0], dt),
        "w_up": common.dense_init(ks[1], (dm, 2 * d_in), 0, dt),
        "conv_w": common.dense_init(ks[2], (cfg.xlstm.conv_width, d_in), 0, dt),
        "wq": common.dense_init(ks[3], (d_in, d_in), 0, dt),
        "wk": common.dense_init(ks[4], (d_in, d_in), 0, dt),
        "wv": common.dense_init(ks[5], (d_in, d_in), 0, dt),
        "wi": common.dense_init(ks[6], (d_in, nh), 0, dt),
        "wf": common.dense_init(ks[6], (d_in, nh), 0, dt),
        "bi": jnp.zeros((nh,), jnp.float32),
        "bf": jnp.full((nh,), 3.0, jnp.float32),   # open forget gates at init
        "gn": jnp.ones((d_in,), dt),
        "w_down": common.dense_init(ks[7], (d_in, dm), 0, dt),
    }


def _mlstm_qkvif(cfg, p, x_norm, conv_state=None):
    """Project inputs.  x_norm: (B,T,dm).  Returns q,k,v (B,T,nh,dh),
    ilog/flog (B,T,nh), z (B,T,d_in), new conv state."""
    d_in, nh, dh = _mlstm_dims(cfg)
    B, T, _ = x_norm.shape
    up = x_norm @ p["w_up"]
    x_in, z = jnp.split(up, 2, axis=-1)
    x_c, conv_state = _causal_conv(p["conv_w"], x_in, conv_state)
    q = (x_c @ p["wq"]).reshape(B, T, nh, dh) * (1.0 / math.sqrt(dh))
    k = (x_c @ p["wk"]).reshape(B, T, nh, dh)
    v = (x_in @ p["wv"]).reshape(B, T, nh, dh)
    ilog = (x_c @ p["wi"]).astype(jnp.float32) + p["bi"]
    flog = jax.nn.log_sigmoid(
        (x_c @ p["wf"]).astype(jnp.float32) + p["bf"])
    return q, k, v, ilog, flog, z, conv_state


def mlstm_chunked(q, k, v, ilog, flog, chunk: int, state=None):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B,T,nh,dh); ilog/flog: (B,T,nh).
    state: {"C": (B,nh,dh,dh), "n": (B,nh,dh), "m": (B,nh)} (stabilized:
    true C = C_hat * exp(m)).  Returns (h (B,T,nh,dh), new state).
    """
    B, T, nh, dh = q.shape
    Q = min(chunk, T)
    T0 = T
    if T % Q:
        pad = Q - T % Q
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        ilog = jnp.pad(ilog, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        flog = jnp.pad(flog, ((0, 0), (0, pad), (0, 0)))  # logf=0 (f=1)
        T = T + pad
    nc = T // Q

    def rs(a):  # (B,T,nh,...) -> (B,nc,nh,Q,...)
        return a.reshape((B, nc, Q) + a.shape[2:]).swapaxes(2, 3)

    qc, kc, vc = rs(q).astype(jnp.float32), rs(k).astype(jnp.float32), \
        rs(v).astype(jnp.float32)
    ic, fc = rs(ilog), rs(flog)                      # (B,nc,nh,Q)
    b = jnp.cumsum(fc, axis=-1)                      # inclusive within chunk
    F = b[..., -1]                                   # (B,nc,nh)

    # intra-chunk decay matrix D[l,s] = b_l - b_s + i_s (s<=l)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    D = jnp.where(tri, b[..., :, None] - b[..., None, :] + ic[..., None, :],
                  NEG)                                # (B,nc,nh,Q,Q)
    m_intra = jnp.max(D, axis=-1)                     # (B,nc,nh,Q)
    # state-injection weights (for chunk state update)
    w_state = F[..., None] - b + ic                   # (B,nc,nh,Q)
    m_state_intra = jnp.max(w_state, axis=-1)         # (B,nc,nh)

    if state is None:
        state = {"C": jnp.zeros((B, nh, dh, dh), jnp.float32),
                 "n": jnp.zeros((B, nh, dh), jnp.float32),
                 "m": jnp.full((B, nh), NEG, jnp.float32)}

    def body(carry, xs):
        C, n, m = carry
        qx, kx, vx, Dx, m_i, b_x, ic_x, F_x, ws_x, msi_x = xs
        # output stabilizer per position
        m_inter = b_x + m[:, :, None]                 # (B,nh,Q)
        m_out = jnp.maximum(m_i, m_inter)
        w = jnp.exp(Dx - m_out[..., None])            # (B,nh,Q,Q)
        scores = jnp.einsum("bhld,bhsd->bhls", qx, kx) * w
        num = jnp.einsum("bhls,bhsd->bhld", scores, vx)
        den = jnp.sum(scores, axis=-1)                # (B,nh,Q)
        qC = jnp.einsum("bhld,bhde->bhle", qx, C)
        scale_inter = jnp.exp(m_inter - m_out)[..., None]
        num = num + qC * scale_inter
        den = den + jnp.einsum("bhld,bhd->bhl", qx, n) * scale_inter[..., 0]
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_out))[..., None]
        # state update
        m_next = jnp.maximum(m + F_x, msi_x)
        wsn = jnp.exp(ws_x - m_next[..., None])       # (B,nh,Q)
        C = C * jnp.exp(m + F_x - m_next)[..., None, None] \
            + jnp.einsum("bhs,bhsd,bhse->bhde", wsn, kx, vx)
        n = n * jnp.exp(m + F_x - m_next)[..., None] \
            + jnp.einsum("bhs,bhsd->bhd", wsn, kx)
        if remat_enabled():
            # train only: backward saves all nc chunk carries — sharding C
            # (dh=1024 for the 4-head xLSTM) keeps them in HBM.  Prefill
            # has no backward; the same constraint would buy an
            # all-gather + reduce PER CHUNK (256 of them at 32k) for
            # nothing — replicated C is 33 MB there.
            C = constrain(C, "batch", None, "model", None)
        return (C, n, m_next), h

    def sw(a):
        """Chunk-major for scan — with the chunk axis REPLICATED.  The
        residual arrives sequence-sharded over 'model'; scanning over a
        sharded chunk axis would trigger a resharding collective per chunk
        per layer (measured: 1.5 TB all-to-all for xlstm prefill_32k).
        One all-gather per layer here instead."""
        a = constrain(a, "batch", *([None] * (a.ndim - 1)))
        return a.swapaxes(0, 1)

    (C, n, m), hs = jax.lax.scan(
        body, (state["C"], state["n"], state["m"]),
        (sw(qc), sw(kc), sw(vc), sw(D), sw(m_intra), sw(b), sw(ic), sw(F),
         sw(w_state), sw(m_state_intra)))
    h = hs.swapaxes(0, 1)                             # (B,nc,nh,Q,dh)
    h = h.swapaxes(2, 3).reshape(B, T, nh, dh)[:, :T0]
    return h.astype(q.dtype), {"C": C, "n": n, "m": m}


def mlstm_reference(q, k, v, ilog, flog, state=None):
    """Naive per-step recurrence oracle (float32, stabilized)."""
    B, T, nh, dh = q.shape
    if state is None:
        state = {"C": jnp.zeros((B, nh, dh, dh), jnp.float32),
                 "n": jnp.zeros((B, nh, dh), jnp.float32),
                 "m": jnp.full((B, nh), NEG, jnp.float32)}

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        m_new = jnp.maximum(ft + m, it)
        fs = jnp.exp(ft + m - m_new)[..., None]
        is_ = jnp.exp(it - m_new)[..., None]
        C = C * fs[..., None] + is_[..., None] * kt[..., :, None] * vt[..., None, :]
        n = n * fs + is_ * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = tuple(a.astype(jnp.float32).swapaxes(0, 1)
               for a in (q, k, v, ilog, flog))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    return hs.swapaxes(0, 1).astype(q.dtype), {"C": C, "n": n, "m": m}


def mlstm_block(cfg: ModelConfig, p: Params, x: jax.Array,
                collect_state: bool = False):
    d_in, nh, dh = _mlstm_dims(cfg)
    B, T, _ = x.shape
    h_in = common.apply_norm(cfg.norm, p["norm"], x)
    q, k, v, ilog, flog, z, conv_state = _mlstm_qkvif(cfg, p, h_in)
    h, st = mlstm_chunked(q, k, v, ilog, flog, chunk=128)
    h = h.reshape(B, T, d_in)
    h = common.apply_norm("rmsnorm", p["gn"],
                          h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype))
    out = h @ p["w_down"]
    out = constrain(out, "batch", None, None)
    state = {**st, "conv": conv_state} if collect_state else None
    return common.seq_shard(x + out), state


def mlstm_decode(cfg: ModelConfig, p: Params, x: jax.Array, state):
    d_in, nh, dh = _mlstm_dims(cfg)
    B = x.shape[0]
    h_in = common.apply_norm(cfg.norm, p["norm"], x)
    q, k, v, ilog, flog, z, conv_state = _mlstm_qkvif(
        cfg, p, h_in, state["conv"])
    st = {"C": state["C"], "n": state["n"], "m": state["m"]}
    h, st = mlstm_reference(q, k, v, ilog, flog, st)   # T=1: one step
    h = h.reshape(B, 1, d_in)
    h = common.apply_norm("rmsnorm", p["gn"],
                          h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype))
    out = x + constrain(h @ p["w_down"], "batch", None, None)
    return out, {**st, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def init_slstm(cfg: ModelConfig, key, dt) -> Params:
    dm = cfg.d_model
    nh = cfg.n_heads
    dh = dm // nh
    d_ff = int(cfg.xlstm.proj_factor_slstm * dm)
    ks = jax.random.split(key, 6)
    return {
        "norm": common.make_norm_params(cfg, ks[0], dt),
        "w_gates": common.dense_init(ks[1], (dm, 4 * dm), 0, dt),   # z,i,f,o
        "r_gates": common.dense_init(ks[2], (4, nh, dh, dh), 2, dt),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * dm,)), jnp.full((dm,), 3.0), jnp.zeros((dm,))]
        ).astype(jnp.float32),
        "gn": jnp.ones((dm,), dt),
        "norm2": common.make_norm_params(cfg, ks[3], dt),
        "ffn_w1": common.dense_init(ks[4], (dm, d_ff), 0, dt),
        "ffn_w3": common.dense_init(ks[4], (dm, d_ff), 0, dt),
        "ffn_w2": common.dense_init(ks[5], (d_ff, dm), 0, dt),
    }


def _slstm_cell_step(p, nh, dh, xw, carry):
    """One time step.  xw: (B, 4*dm) pre-projected input contribution;
    carry: (c, n, h, m) each (B, nh, dh)-shaped except m (B, nh)."""
    c, n, h, m = carry
    B = xw.shape[0]
    dm = nh * dh
    # recurrent contribution: h (B,nh,dh) @ r (4,nh,dh,dh)
    rec = jnp.einsum("bhd,ghde->gbhe", h, p["r_gates"].astype(h.dtype))
    gates = xw.reshape(B, 4, nh, dh).swapaxes(0, 1) + rec
    gates = gates.astype(jnp.float32) \
        + p["b_gates"].reshape(4, 1, nh, dh)
    zt = jnp.tanh(gates[0])
    it = gates[1]                                    # log-space input gate
    ft = jax.nn.log_sigmoid(gates[2])
    ot = jax.nn.sigmoid(gates[3])
    # per-head shared stabilizer (max over head dims)
    it_h = jnp.max(it, axis=-1)                      # (B,nh)
    m_new = jnp.maximum(jnp.max(ft, axis=-1) + m, it_h)
    fs = jnp.exp(ft + (m - m_new)[..., None])
    is_ = jnp.exp(it - m_new[..., None])
    c = fs * c + is_ * zt
    n = fs * n + is_
    h_new = ot * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new.astype(h.dtype), m_new)


def slstm_block(cfg: ModelConfig, p: Params, x: jax.Array,
                state=None, collect_state: bool = False):
    """Full-sequence sLSTM block (scan over time) + gated FFN."""
    dm = cfg.d_model
    nh = cfg.n_heads
    dh = dm // nh
    B, T, _ = x.shape
    h_in = common.apply_norm(cfg.norm, p["norm"], x)
    xw = h_in @ p["w_gates"]                          # (B,T,4dm)
    if state is None:
        z = jnp.zeros((B, nh, dh), jnp.float32)
        state = (z, z, z.astype(x.dtype), jnp.full((B, nh), NEG, jnp.float32))

    def step(carry, xt):
        carry = _slstm_cell_step(p, nh, dh, xt, carry)
        return carry, carry[2]

    state, hs = jax.lax.scan(step, state, xw.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, T, dm)
    h = common.apply_norm("rmsnorm", p["gn"], h)
    x = x + h
    # gated FFN sub-block
    h2 = common.apply_norm(cfg.norm, p["norm2"], x)
    ff = jax.nn.silu(h2 @ p["ffn_w1"]) * (h2 @ p["ffn_w3"])
    ff = constrain(ff, "batch", None, "model")
    x = common.seq_shard(x + constrain(ff @ p["ffn_w2"], "batch", None, None))
    return x, (state if collect_state else None)


def slstm_decode(cfg, p, x, state):
    return slstm_block(cfg, p, x, state=state, collect_state=True)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _layout(cfg: ModelConfig):
    k = cfg.xlstm.slstm_every
    G = cfg.n_layers // k
    tail = cfg.n_layers - G * k          # tail mLSTM layers
    return G, k - 1, tail                # G groups of (k-1 mLSTM + 1 sLSTM)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    G, M, tail = _layout(cfg)
    ks = jax.random.split(key, 6)
    Vp = cfg.vocab_padded()
    p = {
        "embed": common.embed_init(ks[1], (Vp, cfg.d_model), dt),
        "final_norm": common.make_norm_params(cfg, ks[3], dt),
        "lm_head": common.dense_init(ks[4], (cfg.d_model, Vp), 0, dt),
    }
    if G:
        mk = jax.random.split(ks[0], max(G * M, 1))
        mkeys = mk.reshape((G, M) + mk.shape[1:])
        p["mlstm"] = jax.vmap(jax.vmap(lambda k: init_mlstm(cfg, k, dt)))(mkeys)
        p["slstm"] = jax.vmap(lambda k: init_slstm(cfg, k, dt))(
            jax.random.split(ks[2], G))
    if tail:
        p["tail"] = jax.vmap(lambda k: init_mlstm(cfg, k, dt))(
            jax.random.split(ks[5], tail))
    return p


def _run_stack(cfg: ModelConfig, params: Params, x: jax.Array,
               collect: bool):
    G, M, tail = _layout(cfg)

    def m_layer(x, lp):
        x, st = mlstm_block(cfg, lp, x, collect_state=collect)
        return x, st

    def group(x, inputs):
        gp, sp = inputs
        x, m_states = jax.lax.scan(maybe_remat(m_layer), x, gp)
        x, s_state = slstm_block(cfg, sp, x, collect_state=collect)
        return x, (m_states, s_state)

    m_states = s_states = t_states = None
    if G:
        x, (m_states, s_states) = jax.lax.scan(
            maybe_remat(group), x, (params["mlstm"], params["slstm"]))
    if tail:
        x, t_states = jax.lax.scan(maybe_remat(m_layer), x, params["tail"])
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    cache = None
    if collect:
        cache = {}
        if G:
            cache.update({"mlstm": m_states, "slstm": s_states})
        if tail:
            cache["tail"] = t_states
    return x, cache


def forward(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    x = constrain(params["embed"][batch["tokens"]], "batch", None, None)
    x, _ = _run_stack(cfg, params, x, collect=False)
    return x @ params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, batch):
    logits = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab,
                         batch.get("loss_mask"))
    return loss, {"loss": loss}


def prefill(cfg: ModelConfig, params: Params, batch, cache_len: int = 0):
    x = constrain(params["embed"][batch["tokens"]], "batch", None, None)
    x, cache = _run_stack(cfg, params, x, collect=True)
    logits = (x[:, -1:] @ params["lm_head"])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache, tokens, pos):
    G, M, tail = _layout(cfg)
    x = constrain(params["embed"][tokens], "batch", None, None)

    def m_layer(x, inputs):
        lp, st = inputs
        x, st = mlstm_decode(cfg, lp, x, st)
        return x, st

    def group(x, inputs):
        gp, g_st, sp, s_st = inputs
        x, m_states = jax.lax.scan(m_layer, x, (gp, g_st))
        x, s_state = slstm_decode(cfg, sp, x, s_st)
        return x, (m_states, s_state)

    new_cache = {}
    if G:
        x, (m_states, s_states) = jax.lax.scan(
            group, x, (params["mlstm"], cache["mlstm"], params["slstm"],
                       cache["slstm"]))
        new_cache = {"mlstm": m_states, "slstm": s_states}
    if tail:
        x, t_states = jax.lax.scan(m_layer, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = t_states
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """O(1)-in-context recurrent state (cache_len is ignored by design)."""
    dt = _dtype(cfg)
    G, M, tail = _layout(cfg)
    d_in, nh, dh = _mlstm_dims(cfg)
    dms = cfg.d_model // cfg.n_heads
    K = cfg.xlstm.conv_width

    def m_state(lead):
        return {"C": jnp.zeros(lead + (batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros(lead + (batch, nh, dh), jnp.float32),
                "m": jnp.full(lead + (batch, nh), NEG, jnp.float32),
                "conv": jnp.zeros(lead + (batch, K - 1, d_in), dt)}

    cache = {}
    if G:
        z = jnp.zeros((G, batch, cfg.n_heads, dms), jnp.float32)
        cache = {"mlstm": m_state((G, M)),
                 "slstm": (z, z, z.astype(dt),
                           jnp.full((G, batch, cfg.n_heads), NEG,
                                    jnp.float32))}
    if tail:
        cache["tail"] = m_state((tail,))
    return cache


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    return {"tokens": sds((B, 1), jnp.int32)}


def make_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=lambda p, b: forward(cfg, p, b),
        loss_fn=functools.partial(loss_fn, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
