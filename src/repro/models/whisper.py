"""Whisper-style encoder-decoder transformer (audio family).

The mel-spectrogram + conv1d feature extractor is a STUB (assignment
carve-out): ``audio_embeds`` of shape (B, n_frames, d_model) arrive
precomputed.  The encoder is a bidirectional transformer over frames; the
decoder is a causal transformer with cross-attention to the encoder output.

Decode cache = {self-attn slot caches (L,B,W,nkv,dh), static cross-attn k/v
(L,B,F,nkv,dh)} — cross k/v are computed once at prefill.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import common
from repro.models.api import Model, cross_entropy
from repro.utils.remat import maybe_remat
from repro.utils.sharding import constrain

Params = Dict[str, Any]


def _dtype(cfg): return jnp.dtype(cfg.dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    Vp = cfg.vocab_padded()

    def enc_layer(lkey):
        ka, kf, kn = jax.random.split(lkey, 3)
        return {"attn": common.make_attn_params(cfg, ka, dt),
                "ffn": common.make_ffn_params(cfg, kf, dt),
                "norm1": common.make_norm_params(cfg, kn, dt),
                "norm2": common.make_norm_params(cfg, kn, dt)}

    def dec_layer(lkey):
        ka, kx, kf, kn = jax.random.split(lkey, 4)
        return {"attn": common.make_attn_params(cfg, ka, dt),
                "xattn": common.make_attn_params(cfg, kx, dt),
                "ffn": common.make_ffn_params(cfg, kf, dt),
                "norm1": common.make_norm_params(cfg, kn, dt),
                "norm2": common.make_norm_params(cfg, kn, dt),
                "norm3": common.make_norm_params(cfg, kn, dt)}

    return {
        "embed": common.embed_init(ks[0], (Vp, cfg.d_model), dt),
        "enc_layers": jax.vmap(enc_layer)(
            jax.random.split(ks[1], cfg.encdec.n_enc_layers)),
        "dec_layers": jax.vmap(dec_layer)(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": common.make_norm_params(cfg, ks[3], dt),
        "final_norm": common.make_norm_params(cfg, ks[4], dt),
    }


def encode(cfg: ModelConfig, params: Params, audio_embeds: jax.Array
           ) -> jax.Array:
    x = constrain(audio_embeds.astype(_dtype(cfg)), "batch", None, None)
    B, F, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(x, lp):
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        x = x + common.attention_block(lp["attn"], cfg, h, positions,
                                       bidirectional=True)
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        return x + common.ffn_apply(lp["ffn"], cfg, h), None

    x, _ = jax.lax.scan(maybe_remat(body), x, params["enc_layers"])
    return common.apply_norm(cfg.norm, params["enc_norm"], x)


def _cross_attend(lp: Params, cfg: ModelConfig, h: jax.Array,
                  xk: jax.Array, xv: jax.Array) -> jax.Array:
    """h: (B,S,D) queries; xk/xv: (B,F,nkv,dh) precomputed encoder k/v."""
    B, S, _ = h.shape
    q = (h @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    out = common.gqa_attention(q, xk, xv, mask=None)
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["wo"]
    return constrain(out, "batch", None, None)


def _cross_kv(lp: Params, cfg: ModelConfig, enc: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    B, F, _ = enc.shape
    k = (enc @ lp["wk"]).reshape(B, F, cfg.n_kv_heads, cfg.d_head)
    v = (enc @ lp["wv"]).reshape(B, F, cfg.n_kv_heads, cfg.d_head)
    return k, v


def _decoder(cfg: ModelConfig, params: Params, tokens: jax.Array,
             enc: jax.Array, collect_cache: bool, W: int = 0):
    """Teacher-forced decoder pass.  Returns (hidden, cache | None)."""
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        q, k, v = common.qkv_proj(lp["attn"], cfg, h, positions)
        att = common.chunked_causal_attention(q, k, v)
        att = att.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        x = x + constrain(att, "batch", None, None)
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        x = x + _cross_attend(lp["xattn"], cfg, h, *_cross_kv(lp["xattn"], cfg, enc))
        h = common.apply_norm(cfg.norm, lp["norm3"], x)
        x = common.seq_shard(x + common.ffn_apply(lp["ffn"], cfg, h))
        ys = None
        if collect_cache:
            ck, cv = common.prefill_cache_from_kv(k, v, W)
            xk, xv = _cross_kv(lp["xattn"], cfg, enc)
            ys = {"k": ck, "v": cv, "xk": xk, "xv": xv}
        return x, ys

    x, cache = jax.lax.scan(maybe_remat(body), x, params["dec_layers"])
    return common.apply_norm(cfg.norm, params["final_norm"], x), cache


def forward(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    enc = encode(cfg, params, batch["audio_embeds"])
    x, _ = _decoder(cfg, params, batch["tokens"], enc, collect_cache=False)
    return x @ params["embed"].T


def loss_fn(cfg: ModelConfig, params: Params, batch):
    logits = forward(cfg, params, batch)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab,
                         batch.get("loss_mask"))
    return loss, {"loss": loss}


def prefill(cfg: ModelConfig, params: Params, batch, cache_len: int = 0):
    enc = encode(cfg, params, batch["audio_embeds"])
    S = batch["tokens"].shape[1]
    W = cache_len or S
    x, cache = _decoder(cfg, params, batch["tokens"], enc,
                        collect_cache=True, W=W)
    logits = x[:, -1:] @ params["embed"].T
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
                pos: jax.Array):
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)

    def body(x, inputs):
        lp, ck, cv, xk, xv = inputs
        h = common.apply_norm(cfg.norm, lp["norm1"], x)
        att, ck, cv = common.decode_attention(lp["attn"], cfg, h, ck, cv, pos)
        x = x + att
        h = common.apply_norm(cfg.norm, lp["norm2"], x)
        x = x + _cross_attend(lp["xattn"], cfg, h, xk, xv)
        h = common.apply_norm(cfg.norm, lp["norm3"], x)
        x = x + common.ffn_apply(lp["ffn"], cfg, h)
        return x, {"k": ck, "v": cv}

    x, new_sc = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, {"k": new_sc["k"], "v": new_sc["v"],
                    "xk": cache["xk"], "xv": cache["xv"]}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = _dtype(cfg)
    L, W = cfg.n_layers, cache_len
    F = cfg.encdec.n_audio_frames
    kv = (L, batch, W, cfg.n_kv_heads, cfg.d_head)
    xkv = (L, batch, F, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
            "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    F = cfg.encdec.n_audio_frames
    sds = jax.ShapeDtypeStruct
    audio = sds((B, F, cfg.d_model), _dtype(cfg))
    if shape.kind == "train":
        return {"audio_embeds": audio, "tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"audio_embeds": audio, "tokens": sds((B, S), jnp.int32)}
    return {"tokens": sds((B, 1), jnp.int32)}


def make_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=lambda p, b: forward(cfg, p, b),
        loss_fn=functools.partial(loss_fn, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
