"""Shared building blocks: norms, rotary embeddings, GQA attention (full /
sliding-window / decode-with-cache), FFN, and MoE layers.

All functions are functional (params passed explicitly) and scan-friendly.
Sharding is expressed through logical-axis constraints that no-op outside a
launcher-installed axis context (see utils/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.utils.sharding import axis_divisor, constrain

Params = Dict[str, Any]


def mm(x: jax.Array, w) -> jax.Array:
    """Matmul that dispatches quantized weights to the Pallas dequant-matmul
    (QTensor leaves appear after quant.quantize_tree; plain arrays use XLA)."""
    from repro.quant.ptq import QTensor
    if isinstance(w, QTensor):
        from repro.kernels import ops as kops
        return kops.quant_matmul(x, w.q, w.scale.reshape(-1), w.bits,
                                 act_bits=w.act_bits)
    return x @ w


def maybe_dequant(w):
    """Dense-ify a possibly-quantized weight (for einsum/gather sites)."""
    from repro.quant.ptq import QTensor, dequantize
    if isinstance(w, QTensor):
        return dequantize(w)
    return w

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def make_norm_params(cfg: ModelConfig, key, dtype) -> Optional[jax.Array]:
    if cfg.norm == "nonparam_ln":
        return None
    return jnp.ones((cfg.d_model,), dtype)


def apply_norm(kind: str, w: Optional[jax.Array], x: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over the head dim (Qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def make_attn_params(cfg: ModelConfig, key, dtype) -> Params:
    dm, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (dm, cfg.n_heads * dh), 0, dtype),
        "wk": dense_init(ks[1], (dm, cfg.n_kv_heads * dh), 0, dtype),
        "wv": dense_init(ks[2], (dm, cfg.n_kv_heads * dh), 0, dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * dh, dm), 0, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def qkv_proj(p: Params, cfg: ModelConfig, x: jax.Array,
             positions: jax.Array, use_rope: bool = True
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> q (B,S,nh,dh), k/v (B,S,nkv,dh)."""
    B, S, _ = x.shape
    dh = cfg.d_head
    q = mm(x, p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = mm(x, p["wk"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = mm(x, p["wv"]).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    return q, k, v


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: Optional[jax.Array]) -> jax.Array:
    """Grouped-query attention.

    q: (B, Sq, nh, dh); k, v: (B, Sk, nkv, dh); mask broadcastable to
    (B, 1, 1, Sq, Sk) with True = attend.  Returns (B, Sq, nh, dh).
    """
    B, Sq, nh, dh = q.shape
    nkv = k.shape[2]
    G = nh // nkv
    qg = q.reshape(B, Sq, nkv, G, dh)
    scale = 1.0 / math.sqrt(dh)
    # f32 accumulation via preferred_element_type, NOT astype: an explicit
    # convert of k/v is loop-invariant-hoisted by XLA out of the layer scan,
    # materializing the entire stacked KV cache in f32.
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, nh, dh).astype(q.dtype)


def seq_shard(x: jax.Array) -> jax.Array:
    """Sequence-shard a (B, S, D) residual over the model axis (Megatron
    sequence parallelism).  The scan-over-layers carry is what backward
    saves per layer — sharding it is the difference between O(TB) and
    O(GB) of saved activations for the 80+ layer archs.  No-op when S is
    not divisible or no mesh context is installed."""
    return constrain(x, "batch", "model", None)


def _attn_logits_shard(logits: jax.Array) -> jax.Array:
    """Shard (B, H, Q, Sk) attention logits: prefer heads on 'model',
    fall back to the key dim (sequence-parallel softmax) when the head
    count doesn't divide (e.g. 56 heads on a 16-way axis)."""
    d = axis_divisor("model")
    if d <= 1:
        return logits
    H, Sk = logits.shape[1], logits.shape[3]
    if H % d == 0:
        return constrain(logits, "batch", "model", None, None)
    if Sk % d == 0:
        return constrain(logits, "batch", None, None, "model")
    return constrain(logits, "batch", None, None, None)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             window: int = 0, chunk: int = 512,
                             q_offset: int = 0) -> jax.Array:
    """Blocked causal attention: lax.scan over query chunks so the S x S
    score matrix never materializes (XLA-level flash attention; the Pallas
    decode kernel covers the serve path).  Falls back to the direct masked
    form for short sequences.  q: (B,S,nh,dh), k/v: (B,Sk,nkv,dh)."""
    B, S, nh, dh = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    G = nh // nkv
    if S <= chunk or S % chunk:
        mask = causal_mask(S, Sk, window, q_offset)
        return gqa_attention(q, k, v, mask)
    nb = S // chunk
    k_r = jnp.repeat(k, G, axis=2) if G > 1 else k    # (B, Sk, nh, dh)
    v_r = jnp.repeat(v, G, axis=2) if G > 1 else v
    k_r = constrain(k_r, "batch", None, "model", None)
    v_r = constrain(v_r, "batch", None, "model", None)
    scale = 1.0 / math.sqrt(dh)
    kpos = jnp.arange(Sk)[None, :]

    def body(carry, inp):
        i, qb = inp                                   # qb (B, chunk, nh, dh)
        logits = jnp.einsum("bqhd,bshd->bhqs", qb, k_r,
                            preferred_element_type=jnp.float32) * scale
        logits = _attn_logits_shard(logits)
        qpos = (i * chunk + q_offset) + jnp.arange(chunk)[:, None]
        m = kpos <= qpos
        if window > 0:
            m &= kpos > qpos - window
        logits = jnp.where(m[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v_r,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(q.dtype)

    qb = q.reshape(B, nb, chunk, nh, dh).swapaxes(0, 1)
    _, outs = jax.lax.scan(jax.checkpoint(body), 0,
                           (jnp.arange(nb), qb))
    return outs.swapaxes(0, 1).reshape(B, S, nh, dh)


def causal_mask(Sq: int, Sk: int, window: int = 0,
                q_offset: int = 0) -> jax.Array:
    """(1,1,1,Sq,Sk) boolean mask; window=0 => plain causal; window>0 adds a
    sliding-window lower bound.  q_offset shifts query positions (cross-epoch
    chunked prefill)."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None, None]


def attention_block(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, window: int = 0,
                    bidirectional: bool = False,
                    use_rope: bool = True) -> jax.Array:
    """Full (training / prefill) self-attention with residual projection.
    Returns attn output (B, S, D) (no residual add)."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, cfg, x, positions, use_rope)
    if bidirectional:
        out = gqa_attention(q, k, v, None)
    else:
        out = chunked_causal_attention(q, k, v, window)
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    out = mm(out, p["wo"])
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Decode attention over a slot cache
# ---------------------------------------------------------------------------
# Cache layout: k/v (B, W, nkv, dh) where W = cache capacity (= full seq for
# dense, = window for SWA).  Position p writes slot p % W; since rope is
# applied before caching, attention is permutation-invariant over slots and a
# validity count suffices for masking.
#
# kv_bits=8 (paper §II-B.3 applied to the serving runtime): the cache
# stores int8 values + per-(slot, kv-head) f32 scales.  At decode the
# 32k x 128-request cache is THE dominant HBM traffic (1.5 TB vs 246 GB of
# weights for mistral-large), so halving its bytes halves the memory
# roofline term; dequant happens tile-wise on the way into the MXU.


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(B, S, nkv, dh) -> int8 values + per-(B,S,nkv) f32 scales."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -128, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_write(cache_k: jax.Array, cache_v: jax.Array, k1: jax.Array,
                v1: jax.Array, pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Write one token's k/v (B,1,nkv,dh) at slot pos % W.

    Implemented as a one-hot ``where`` (elementwise) rather than
    dynamic_update_slice: updating a slot-sharded cache must not force
    GSPMD to re-gather the 32k-slot dim on every decode step.
    """
    W = cache_k.shape[1]
    idx = (pos % W).astype(jnp.int32)
    hit = (jnp.arange(W) == idx)[None, :, None, None]
    ck = jnp.where(hit, k1.astype(cache_k.dtype), cache_k)
    cv = jnp.where(hit, v1.astype(cache_v.dtype), cache_v)
    return ck, cv


def decode_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, use_rope: bool = True,
                     use_kernel: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode step.  x: (B, 1, D); pos: scalar current position.
    Returns (out (B,1,D), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    if use_kernel:
        from repro.kernels import ops as kops
        if kops.fusable_decode(p, cfg):
            # fused tier: QKV/output projections consume the int8 weight
            # tiles inside the decode grid; the kernel attends over the
            # pre-write cache + current token, caller writes k1/v1 after
            o, k1, v1 = kops.flash_decode_fused(
                x[:, 0], p["wq"], p["wk"], p["wv"], p["wo"], cache_k,
                cache_v, pos, rope_theta=cfg.rope_theta, use_rope=use_rope)
            ck, cv = cache_write(cache_k, cache_v, k1[:, None], v1[:, None],
                                 pos)
            return constrain(o[:, None], "batch", None, None), ck, cv
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k1, v1 = qkv_proj(p, cfg, x, positions, use_rope)
    ck, cv = cache_write(cache_k, cache_v, k1, v1, pos)
    W = ck.shape[1]
    n_valid = jnp.minimum(pos + 1, W)
    mask = (jnp.arange(W) < n_valid)[None, None, None, None, :]
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_decode(q[:, 0], ck, cv, n_valid)
        out = out[:, None]
    else:
        out = gqa_attention(q, ck, cv, mask)
    out = mm(out.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"])
    return constrain(out, "batch", None, None), ck, cv


def decode_attention_cache(p: Params, cfg: ModelConfig, x: jax.Array,
                           cache: Dict[str, jax.Array], pos: jax.Array,
                           use_kernel: bool = False
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Dict-cache decode step supporting int8 KV (cfg.kv_bits == 8).

    cache: {"k","v"} (+ {"ks","vs"} scales when quantized).  Returns
    (out (B,1,D), new cache dict).  ``use_kernel`` routes the fp-cache
    path through the Pallas decode kernels (fused quantized flavor when
    the projections are int8 QTensors).
    """
    if cfg.kv_bits != 8:
        out, ck, cv = decode_attention(p, cfg, x, cache["k"], cache["v"],
                                       pos, use_kernel=use_kernel)
        return out, {"k": ck, "v": cv}
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k1, v1 = qkv_proj(p, cfg, x, positions)
    k1q, k1s = quantize_kv(k1)
    v1q, v1s = quantize_kv(v1)
    W = cache["k"].shape[1]
    idx = (pos % W).astype(jnp.int32)
    hit = (jnp.arange(W) == idx)[None, :, None]
    ck = jnp.where(hit[..., None], k1q, cache["k"])
    cv = jnp.where(hit[..., None], v1q, cache["v"])
    ks = jnp.where(hit, k1s, cache["ks"])
    vs = jnp.where(hit, v1s, cache["vs"])
    dt = _dt = x.dtype
    # dequant tile-wise into the attention reads (fused on TPU)
    kd = dequantize_kv(ck, ks, dt)
    vd = dequantize_kv(cv, vs, dt)
    n_valid = jnp.minimum(pos + 1, W)
    mask = (jnp.arange(W) < n_valid)[None, None, None, None, :]
    out = gqa_attention(q, kd, vd, mask)
    out = mm(out.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"])
    return constrain(out, "batch", None, None), \
        {"k": ck, "v": cv, "ks": ks, "vs": vs}


def decode_attention_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                           pages: Dict[str, jax.Array], table: jax.Array,
                           pos: jax.Array, use_kernel: bool = False
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode step over a PAGED cache (DESIGN.md §2.3).

    pages: one layer's slice of the node-wide arena — {"k","v"} of shape
    (P, block_tokens, nkv', dh') (+ {"ks","vs"} (P, block_tokens, nkv')
    scales when cfg.kv_bits == 8); table: (B, n_b) int32 mapping logical
    block j of row b to its physical page.  Page tails may be LARGER
    than this model's (nkv, dh) — the node pool provisions the max over
    hosted cohorts — so every write targets and every read slices the
    leading (nkv, dh) corner; the padding is zero-initialized and never
    observed.  The token is written at page ``table[b, pos // bt]``
    offset ``pos % bt``; attention then gathers the row's logical blocks
    back into the (B, n_b*bt, nkv, dh) view — bitwise the contiguous
    cache when the pages hold the same values, which is what makes the
    paged engine path bit-identical to the slab path (rows whose table
    points at the shared trash page are dead and never emit again, so
    their garbage is unobservable).  ``use_kernel`` routes the read
    through ``flash_decode_paged`` (no gather; TPU path, fp cache only).
    """
    B = x.shape[0]
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    bt = pages["k"].shape[1]
    n_b = table.shape[1]
    W = n_b * bt
    # physical page holding this step's write block, per row
    blk = (pos // bt).astype(jnp.int32)
    page = jnp.take_along_axis(table, jnp.broadcast_to(blk, (B,))[:, None],
                               axis=1)[:, 0]                     # (B,)
    off = (pos % bt).astype(jnp.int32)
    if use_kernel and cfg.kv_bits != 8:
        from repro.kernels import ops as kops
        if kops.fusable_decode(p, cfg):
            o, k1f, v1f = kops.flash_decode_fused_paged(
                x[:, 0], p["wq"], p["wk"], p["wv"], p["wo"],
                pages["k"][..., :nkv, :dh], pages["v"][..., :nkv, :dh],
                table, pos, rope_theta=cfg.rope_theta)
            pk = pages["k"].at[page, off, :nkv, :dh].set(
                k1f.astype(pages["k"].dtype))
            pv = pages["v"].at[page, off, :nkv, :dh].set(
                v1f.astype(pages["v"].dtype))
            return constrain(o[:, None], "batch", None, None), \
                {"k": pk, "v": pv}
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k1, v1 = qkv_proj(p, cfg, x, positions)

    def gather(pleaf):
        """Row-major view of a row's logical blocks, tail-sliced to this
        model's geometry: (B, W, nkv[, dh])."""
        g = pleaf[table]                     # (B, n_b, bt, *tail')
        g = g[..., :nkv, :dh] if g.ndim == 5 else g[..., :nkv]
        return g.reshape((B, W) + g.shape[3:])

    if cfg.kv_bits == 8:
        k1q, k1s = quantize_kv(k1)
        v1q, v1s = quantize_kv(v1)
        pk = pages["k"].at[page, off, :nkv, :dh].set(k1q[:, 0])
        pv = pages["v"].at[page, off, :nkv, :dh].set(v1q[:, 0])
        pks = pages["ks"].at[page, off, :nkv].set(k1s[:, 0])
        pvs = pages["vs"].at[page, off, :nkv].set(v1s[:, 0])
        new_pages = {"k": pk, "v": pv, "ks": pks, "vs": pvs}
        dt = x.dtype
        kd = dequantize_kv(gather(pk), gather(pks), dt)
        vd = dequantize_kv(gather(pv), gather(pvs), dt)
    else:
        pk = pages["k"].at[page, off, :nkv, :dh].set(
            k1[:, 0].astype(pages["k"].dtype))
        pv = pages["v"].at[page, off, :nkv, :dh].set(
            v1[:, 0].astype(pages["v"].dtype))
        new_pages = {"k": pk, "v": pv}
        kd = vd = None
    n_valid = jnp.minimum(pos + 1, W)
    if use_kernel and cfg.kv_bits != 8:
        from repro.kernels import ops as kops
        out = kops.flash_decode_paged(q[:, 0], pk[..., :nkv, :dh],
                                      pv[..., :nkv, :dh], table, n_valid)
        out = out[:, None]
    else:
        if kd is None:
            kd, vd = gather(pk), gather(pv)
        mask = (jnp.arange(W) < n_valid)[None, None, None, None, :]
        out = gqa_attention(q, kd, vd, mask)
    out = mm(out.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"])
    return constrain(out, "batch", None, None), new_pages


def prefill_cache_from_kv(k: jax.Array, v: jax.Array, W: int
                          ) -> Tuple[jax.Array, jax.Array]:
    """Build the slot cache from prefill k/v (B, S, nkv, dh).

    Positions p land at slot p % W; only the last W positions survive.
    """
    B, S, nkv, dh = k.shape
    ck = jnp.zeros((B, W, nkv, dh), k.dtype)
    cv = jnp.zeros((B, W, nkv, dh), v.dtype)
    start = max(0, S - W)
    pos = jnp.arange(start, S)
    slots = pos % W
    ck = ck.at[:, slots].set(k[:, start:])
    cv = cv.at[:, slots].set(v[:, start:])
    # slot caches shard over batch + slots (32k x 128-batch caches are the
    # dominant serving footprint; see launch/steps.cache_specs)
    ck = constrain(ck, "batch", "model", None, None)
    cv = constrain(cv, "batch", "model", None, None)
    return ck, cv


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def make_ffn_params(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None
                    ) -> Params:
    dm = cfg.d_model
    df = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":   # gated (SwiGLU)
        return {"w1": dense_init(ks[0], (dm, df), 0, dtype),
                "w3": dense_init(ks[1], (dm, df), 0, dtype),
                "w2": dense_init(ks[2], (df, dm), 0, dtype)}
    return {"w1": dense_init(ks[0], (dm, df), 0, dtype),
            "w2": dense_init(ks[2], (df, dm), 0, dtype)}


def ffn_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(mm(x, p["w1"])) * mm(x, p["w3"])
    elif cfg.act == "gelu":
        h = jax.nn.gelu(mm(x, p["w1"]))
    else:
        h = jax.nn.relu(mm(x, p["w1"]))
    h = constrain(h, "batch", None, "model")
    out = mm(h, p["w2"])
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k with capacity dispatch)
# ---------------------------------------------------------------------------


def make_moe_params(cfg: ModelConfig, key, dtype) -> Params:
    E, dm, df = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (dm, E), 0, dtype),
         "w1": dense_init(ks[1], (E, dm, df), 1, dtype),
         "w2": dense_init(ks[2], (E, df, dm), 1, dtype)}
    if cfg.act == "silu":
        p["w3"] = dense_init(ks[3], (E, dm, df), 1, dtype)
    return p


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Top-k token-choice MoE with per-expert capacity.

    x: (B, S, D).  Returns (out, aux_loss).  Dispatch/combine are one-hot
    scatter/gathers so the per-expert compute is E*C*D*F (≈ active FLOPs ×
    capacity_factor), not E×T full compute.
    """
    B, S, D = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.top_k
    T = B * S
    d = axis_divisor("model")
    expert_parallel = E % d == 0
    # Non-expert-parallel (E doesn't divide the axis, e.g. Mixtral's 8 on
    # 16): token dims sharded over the batch axes throughout — GSPMD
    # cannot propagate through the dispatch scatter and every (.., C, ..)
    # buffer would otherwise materialize at GLOBAL capacity.  The
    # expert-parallel path must NOT get these: token constraints fight the
    # E-sharded scatter and replicate the (T*K, D) dispatch instead
    # (measured: granite-moe train 15 -> 131 GiB).
    tok = (lambda a: constrain(a, "batch", *([None] * (a.ndim - 1)))) \
        if not expert_parallel else (lambda a: a)
    xt = tok(x.reshape(T, D))
    gate_logits = mm(xt, p["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)                # (T, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    C = int(math.ceil(T * K / E * capacity_factor))
    C = max(C, 1)
    # position of each (token, k) assignment within its expert's buffer
    flat_idx = gate_idx.reshape(-1)                            # (T*K,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)      # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)           # pre-count
    pos = jnp.take_along_axis(pos_in_e, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < C
    buf = jnp.zeros((E, C, D), xt.dtype)
    tok_ids = jnp.repeat(jnp.arange(T), K)
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = buf.at[flat_idx, safe_pos].add(
        jnp.where(keep[:, None], xt[tok_ids], 0).astype(xt.dtype))

    # Two MoE layouts (must AGREE with launch/steps param rules — fighting
    # the weight sharding makes GSPMD materialize (E, C, d_ff) unsharded):
    #  * E % model == 0: expert parallel — buf/h/eout sharded on E;
    #  * otherwise: per-expert tensor parallel — h sharded on d_ff exactly
    #    like w1/w3; w2's contraction over d_ff psums back to replicated.
    buf = constrain(buf, "model", None, None) if expert_parallel \
        else constrain(buf, None, "batch", None)

    # expert FFN over (E, C, D)
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, maybe_dequant(p["w1"]))) \
            * jnp.einsum("ecd,edf->ecf", buf, maybe_dequant(p["w3"]))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, maybe_dequant(p["w1"])))
    h = constrain(h, "model", None, None) if expert_parallel \
        else constrain(h, None, "batch", "model")
    eout = jnp.einsum("ecf,efd->ecd", h, maybe_dequant(p["w2"]))
    eout = constrain(eout, "model", None, None) if expert_parallel \
        else constrain(eout, None, "batch", None)

    # combine
    gathered = eout[flat_idx, safe_pos]                        # (T*K, D)
    gathered = tok(jnp.where(keep[:, None], gathered, 0))
    w = gate_w.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, D), xt.dtype).at[tok_ids].add(gathered * w)
    out = tok(out)
    return out.reshape(B, S, D), aux
