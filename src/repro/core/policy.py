"""SchedulerPolicy: the pluggable control plane behind the epoch runtime.

A policy is a class with two methods:

    schedule(env, queue) -> Decision     pick this epoch's batch(es)
    validate(env, decision) -> bool      the policy's own feasibility oracle

carrying its own oracle is the point: the runtime re-checks every decision
without knowing which problem variant the policy solves (P1 for batch
schedulers, the per-unit NoB constraints, or the shared-budget joint
problem for multi-LLM) — this replaces the old ``is_nob`` scheduler-name
string matching in the simulation loop.

``Decision`` holds one batch per hosted model (single-model policies use
the ``None`` key), so ``multi_dftsp`` is a first-class policy instead of a
signature outlier.

Policies are registered by decorator and built from parameterized string
specs::

    get_policy("dftsp")                      # defaults
    get_policy("dftsp:d_sweep=false")        # fast heuristic variant
    get_policy("multi-dftsp:order=name")     # joint scheduler, name order

``policy.spec`` reconstructs the canonical spec (registry round-trip:
``get_policy(get_policy(s).spec).spec == s`` for canonical ``s``).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

from repro.core import multi as _multi
from repro.core import problem
from repro.core import schedulers as _legacy
from repro.core.dftsp import (SearchStats, dftsp_schedule,
                              dftsp_schedule_auto, dftsp_schedule_split)
from repro.core.environment import EdgeEnv
from repro.core.quantization import METHODS, QuantMethod, get_method
from repro.core.request import Request

Env = Union[EdgeEnv, "_multi.MultiLLMEnv"]


class InfeasibleDecisionError(RuntimeError):
    """A scheduling decision failed its policy's own feasibility oracle.

    Raised by the runtime's authoritative re-check (and by executor
    capacity clamping) when ``policy.validate`` rejects what
    ``policy.schedule`` produced — i.e. the scheduler cheated its own
    contract.  A dedicated exception rather than a bare ``assert`` so the
    control-plane contract survives ``python -O``.
    """


class DrainStallError(RuntimeError):
    """The end-of-run drain stopped making progress (a wedged executor,
    a cohort that can never finish, or the drain bound exhausted).

    Replaces the historical bare ``RuntimeError("continuous drain did
    not converge")``: instead of losing the whole run, the error carries
    the PARTIAL :class:`~repro.core.metrics.EpochMetrics` accumulated so
    far (with ``in_flight_rids`` naming the rows still resident) so
    callers can account for every request even when the node wedges —
    the conservation invariant ``arrived == served + dropped + shed +
    queued + in_flight`` stays checkable from the exception alone.
    """

    def __init__(self, message: str, metrics=None,
                 resident_rids: Sequence[int] = ()):
        super().__init__(message)
        self.metrics = metrics
        self.resident_rids = list(resident_rids)


@dataclass
class Decision:
    """One epoch's scheduling outcome: per-model batches + per-model
    quantization assignments + search stats.

    Single-model policies put their batch under the ``None`` key; the
    multi-LLM policy keys batches by hosted ``model_id``.  ``quants``
    records the method the control plane decided for each batch; a
    missing key means "the env's deployed method" (so fixed-method
    policies stay bit-identical to the pre-decision behavior).

    ``splits`` carries the split-epoch extension (DESIGN.md §1.1): when a
    model's entry is present, its epoch queue is served as that ordered
    list of ``(sub_batch, method)`` pairs — sequentially, each at its own
    precision, with the weight-swap cost between them charged in epoch
    time.  The flat ``batches[mid]`` ALWAYS equals the concatenation of
    the sub-batches (so ``selected``/``size``/executor admission are
    split-agnostic), and ``quants[mid]`` records the PRIMARY (first)
    sub-batch's method.
    """
    batches: Dict[Optional[str], List[Request]]
    stats: SearchStats = field(default_factory=SearchStats)
    quants: Dict[Optional[str], QuantMethod] = field(default_factory=dict)
    splits: Dict[Optional[str], List[Tuple[List[Request], QuantMethod]]] = \
        field(default_factory=dict)

    @classmethod
    def single(cls, selected: Sequence[Request],
               stats: Optional[SearchStats] = None,
               quant: Optional[QuantMethod] = None) -> "Decision":
        return cls(batches={None: list(selected)},
                   stats=stats or SearchStats(),
                   quants={} if quant is None else {None: quant})

    def sub_batches(self, model_id: Optional[str], env: Env
                    ) -> List[Tuple[List[Request], QuantMethod]]:
        """The (batch, method) sub-batches serving ``model_id`` — the
        recorded split when one exists, else the whole batch at the
        decided (or deployed) method."""
        subs = self.splits.get(model_id)
        if subs:
            return subs
        batch = self.batches.get(model_id, [])
        return [(batch, self.quant_for(model_id, env))] if batch else []

    def quant_for(self, model_id: Optional[str], env: Env) -> QuantMethod:
        """The method this decision serves ``model_id`` with (falls back
        to the deployment default frozen in the env)."""
        q = self.quants.get(model_id)
        if q is not None:
            return q
        if isinstance(env, _multi.MultiLLMEnv):
            return env.envs[model_id].quant
        return env.quant

    @property
    def selected(self) -> List[Request]:
        """All scheduled requests, flattened in model order."""
        return [r for batch in self.batches.values() for r in batch]

    @property
    def size(self) -> int:
        return sum(len(b) for b in self.batches.values())


class SchedulerPolicy:
    """Base class: one scheduling algorithm + its feasibility oracle."""

    name: str = "?"

    def schedule(self, env: Env, queue: Sequence[Request]) -> Decision:
        raise NotImplementedError

    def validate(self, env: Env, decision: Decision) -> bool:
        """Default oracle: the full P1 constraint set on the flat batch,
        evaluated under the decision's quant assignment (if any)."""
        return problem.feasible(env, decision.selected,
                                quant=decision.quants.get(None))

    def select_quant(self, env: Env, model_id: Optional[str],
                     batch: Sequence[Request]) -> Optional[QuantMethod]:
        """The method a freshly starting continuous-batching COHORT of
        ``model_id`` should be served with, given the queued requests
        ``batch`` it would be built from (``None`` = the env's deployed
        method).

        The continuous runtime never calls ``schedule()`` — admission
        replaces batch selection — so this is where the quantization
        decision surfaces on that path: policies with a pinned method
        return it, and ``quant="auto"`` policies run the PR-2 descent
        (accuracy prefilter + Pareto pruning + (z, method) descent) over
        the prospective cohort pool.  The default keeps the deployed
        method, which is bit-identical to the pre-decision behavior.
        """
        return None

    @property
    def spec(self) -> str:
        """Canonical registry spec (non-default constructor params only)."""
        parts = []
        sig = inspect.signature(type(self).__init__)
        for k, p in sig.parameters.items():
            if k == "self" or p.default is inspect.Parameter.empty:
                continue
            v = getattr(self, k, p.default)
            if v != p.default:
                parts.append(f"{k}={_format_value(v)}")
        return self.name + (":" + ",".join(sorted(parts)) if parts else "")

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.spec!r}>"


# ---------------------------------------------------------------------------
# Registry: decorator + parameterized string specs
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[SchedulerPolicy]] = {}


def register(name: str) -> Callable[[Type[SchedulerPolicy]],
                                    Type[SchedulerPolicy]]:
    """Class decorator: make a policy buildable via ``get_policy(name)``."""
    def deco(cls: Type[SchedulerPolicy]) -> Type[SchedulerPolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def _coerce_value(text: str):
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text


def _format_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


def parse_spec(spec: str) -> Tuple[str, Dict[str, object]]:
    """``"name:key=val,key2=val2"`` -> (name, params).  Values are coerced
    to bool/int/float when they parse as one."""
    name, _, tail = spec.partition(":")
    params: Dict[str, object] = {}
    if tail:
        for item in tail.split(","):
            k, sep, v = item.partition("=")
            if not sep:
                raise ValueError(f"malformed policy spec {spec!r}: "
                                 f"expected key=value, got {item!r}")
            params[k.strip()] = _coerce_value(v.strip())
    return name.strip(), params


def get_policy(spec: Union[str, SchedulerPolicy]) -> SchedulerPolicy:
    """Build a policy from a registry spec (idempotent on policy objects)."""
    if isinstance(spec, SchedulerPolicy):
        return spec
    name, params = parse_spec(spec)
    cls = _REGISTRY.get(name)
    if cls is None:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{sorted(_REGISTRY)}")
    try:
        return cls(**params)
    except TypeError as e:
        raise TypeError(f"bad params for policy {name!r}: {e}") from e


def available() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Single-model policies (wrapping the pure scheduling functions)
# ---------------------------------------------------------------------------


def _resolve_quant_param(quant: str) -> Optional[QuantMethod]:
    """``"env"`` -> None (deployed method), ``"auto"`` handled by callers,
    else a METHODS name (e.g. ``"W4A16-GPTQ"``)."""
    if quant == "env":
        return None
    if quant not in METHODS:
        raise ValueError(f"unknown quant selector {quant!r} "
                         f"(expected env|auto|{'|'.join(sorted(METHODS))})")
    return get_method(quant)


@register("dftsp")
class DftspPolicy(SchedulerPolicy):
    """Paper Algorithm 1 (optimal DFS tree search with online pruning).

    ``quant`` turns the Fig. 6 trade-off into a scheduling decision:
    ``"env"`` (default) keeps the env's deployed method, a METHODS name
    pins an explicit method, and ``"auto"`` selects the
    throughput-optimal admissible method per epoch
    (``dftsp_schedule_auto``).

    ``calib`` picks the coefficient source the ``auto`` descent runs on:
    ``"table2"`` (default) uses the paper's Table-II METHODS, and
    ``"measured"`` uses engine-measured records installed via
    :meth:`install_measured` (quant/calibration.measured_methods) — the
    scheduler then optimizes for the engine it actually drives.
    """

    def __init__(self, prune: bool = True, order_desc: bool = True,
                 d_sweep: bool = True, fast_z_bound: bool = True,
                 quant: str = "env", calib: str = "table2",
                 split: bool = False):
        if calib not in ("table2", "measured"):
            raise ValueError(f"unknown calib source {calib!r} "
                             "(expected table2|measured)")
        if split and quant != "auto":
            raise ValueError("split=true needs quant=auto — a split epoch "
                             "is a choice BETWEEN methods per sub-batch")
        self.prune = prune
        self.order_desc = order_desc
        self.d_sweep = d_sweep
        self.fast_z_bound = fast_z_bound
        self.quant = quant
        self.calib = calib
        self.split = split
        self._measured: Optional[Dict[str, QuantMethod]] = None
        self._swap_record: Optional[Dict] = None
        if quant != "auto":
            _resolve_quant_param(quant)     # fail fast on bad names

    def install_measured(self, methods: Dict[str, QuantMethod]) -> None:
        """Install engine-measured QuantMethod records (used by the auto
        descent when ``calib="measured"``)."""
        self._measured = dict(methods)

    def install_swap_costs(self, record: Optional[Dict]) -> None:
        """Install a ``quant/calibration.measure_swap_cost`` record: the
        split descent and the split oracle then charge the MEASURED
        weight-swap latency between sub-batch methods (no record = the
        Table-II reproduction's free-swap pricing)."""
        self._swap_record = dict(record) if record else None

    def _method_pool(self):
        """The candidate METHODS the auto descent draws from, or None for
        the Table-II default."""
        if self.calib != "measured":
            return None
        if self._measured is None:
            raise RuntimeError(
                "calib='measured' needs install_measured() — run "
                "quant/calibration.measure_beta on the serving engine "
                "and install measured_methods() first")
        return list(self._measured.values())

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        kw = dict(prune=self.prune, order_desc=self.order_desc,
                  d_sweep=self.d_sweep, fast_z_bound=self.fast_z_bound)
        if self.split:
            subs, stats = dftsp_schedule_split(
                env, queue, methods=self._method_pool(),
                swap_record=self._swap_record, **kw)
            flat = [r for b, _ in subs for r in b]
            return Decision(
                batches={None: flat}, stats=stats,
                quants={None: subs[0][1]} if subs else {},
                splits={None: subs} if len(subs) > 1 else {})
        if self.quant == "auto":
            sel, method, stats = dftsp_schedule_auto(
                env, queue, methods=self._method_pool(), **kw)
            return Decision.single(sel, stats, quant=method)
        q = _resolve_quant_param(self.quant)
        sel, stats = dftsp_schedule(env, queue, quant=q, **kw)
        return Decision.single(sel, stats, quant=q)

    def validate(self, env: EdgeEnv, decision: Decision) -> bool:
        """Split-aware oracle: a split decision is checked per sub-batch
        at its OWN method with the swap cost charged serially
        (``problem.split_feasible``); single-method decisions keep the
        historical flat P1 check."""
        subs = decision.splits.get(None)
        if subs:
            return problem.split_feasible(env, subs,
                                          swap_record=self._swap_record)
        return super().validate(env, decision)

    def select_quant(self, env: EdgeEnv, model_id: Optional[str],
                     batch: Sequence[Request]) -> Optional[QuantMethod]:
        if self.quant == "env" or not batch:
            return None
        if self.quant != "auto":
            return _resolve_quant_param(self.quant)
        _, method, _ = dftsp_schedule_auto(env, list(batch),
                                           methods=self._method_pool())
        return method


@register("brute_force")
class BruteForcePolicy(SchedulerPolicy):
    """Un-pruned, un-ordered tree search (Table III benchmark)."""

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        sel, stats = dftsp_schedule(env, queue, prune=False,
                                    order_desc=False, fast_z_bound=False)
        return Decision.single(sel, stats)


@register("stb")
class StaticBatchingPolicy(SchedulerPolicy):
    """StB: FIFO admission up to the offline worst-case batch size."""

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        sel, stats = _legacy.static_batching(env, queue)
        return Decision.single(sel, stats)

    def batch_size(self, env: EdgeEnv) -> int:
        """The memoized offline batch size this policy admits up to."""
        return _legacy.static_batch_size(env)


@register("nob")
class NoBatchingPolicy(SchedulerPolicy):
    """NoB: one request per accelerator unit.  Its oracle is per-unit
    (1/n_units of compute+memory, true prompt length), NOT batched P1."""

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        sel, stats = _legacy.no_batching(env, queue)
        return Decision.single(sel, stats)

    def validate(self, env: EdgeEnv, decision: Decision) -> bool:
        return _legacy.nob_feasible(env, decision.selected)


@register("greedy")
class GreedyPolicy(SchedulerPolicy):
    """Slack-then-cost greedy admission (beyond-paper heuristic anchor)."""

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        sel, stats = _legacy.greedy(env, queue)
        return Decision.single(sel, stats)


class CallablePolicy(SchedulerPolicy):
    """Adapter for legacy ``(env, requests) -> (selected, stats)``
    callables (e.g. the capped searchers in benchmarks/table3)."""

    name = "callable"

    def __init__(self, fn: _legacy.Scheduler,
                 oracle: Optional[Callable[[EdgeEnv, Sequence[Request]],
                                           bool]] = None):
        self.fn = fn
        self.oracle = oracle

    def schedule(self, env: EdgeEnv, queue: Sequence[Request]) -> Decision:
        sel, stats = self.fn(env, queue)
        return Decision.single(sel, stats)

    def validate(self, env: EdgeEnv, decision: Decision) -> bool:
        if self.oracle is not None:
            return self.oracle(env, decision.selected)
        return problem.feasible(env, decision.selected)

    @property
    def spec(self) -> str:
        return f"callable:{getattr(self.fn, '__name__', repr(self.fn))}"


# ---------------------------------------------------------------------------
# Multi-LLM joint policy (first-class, same registry/runtime as the rest)
# ---------------------------------------------------------------------------


@register("multi-dftsp")
class MultiDftspPolicy(SchedulerPolicy):
    """Joint DFTSP over a MultiLLMEnv's hosted models (residual budgets,
    sequential compute slot).  ``order`` picks the model visit order;
    ``quant="auto"`` selects each hosted model's method per epoch."""

    def __init__(self, order: str = "weight", quant: str = "env",
                 split: bool = False):
        if order not in ("weight", "name", "load"):
            raise ValueError(f"unknown model order {order!r} "
                             "(expected weight|name|load)")
        if split and quant != "auto":
            raise ValueError("split=true needs quant=auto — a split epoch "
                             "is a choice BETWEEN methods per sub-batch")
        self.order = order
        self.quant = quant
        self.split = split
        self._swap_record: Optional[Dict] = None
        if quant != "auto":
            _resolve_quant_param(quant)     # fail fast on bad names

    def schedule(self, menv: "_multi.MultiLLMEnv",
                 queue: Sequence[Request]) -> Decision:
        if self.split:
            batches, quants, splits, stats = \
                _multi.multi_dftsp_assign_split(
                    menv, queue, order=self.order, quant=self.quant,
                    swap_record=self._swap_record)
            return Decision(batches=dict(batches), stats=stats,
                            quants=dict(quants), splits=dict(splits))
        batches, quants, stats = _multi.multi_dftsp_assign(
            menv, queue, order=self.order, quant=self.quant)
        if self.quant == "env":
            quants = {}         # deployment defaults: record no override
        return Decision(batches=dict(batches), stats=stats,
                        quants=dict(quants))

    def validate(self, menv: "_multi.MultiLLMEnv",
                 decision: Decision) -> bool:
        return _multi.multi_feasible(menv, decision.batches,
                                     order=self.order,
                                     quants=decision.quants,
                                     splits=decision.splits,
                                     swap_record=self._swap_record)

    def install_measured(self, methods: Dict[str, QuantMethod]) -> None:
        """Engine-measured QuantMethod records for the per-cohort auto
        descent (same contract as DftspPolicy.install_measured)."""
        self._measured = dict(methods)

    def install_swap_costs(self, record: Optional[Dict]) -> None:
        """Measured weight-swap record for split pricing (same contract
        as DftspPolicy.install_swap_costs)."""
        self._swap_record = dict(record) if record else None

    def select_quant(self, menv: "_multi.MultiLLMEnv",
                     model_id: Optional[str],
                     batch: Sequence[Request]) -> Optional[QuantMethod]:
        """Per-cohort method for the continuous path: the PR-2 descent on
        this model's single-model view (the joint budgets are enforced by
        the admission oracle, not here)."""
        if self.quant == "env" or not batch:
            return None
        if self.quant != "auto":
            return get_method(self.quant)
        measured = getattr(self, "_measured", None)
        _, method, _ = dftsp_schedule_auto(
            menv.envs[model_id], list(batch),
            methods=None if measured is None else list(measured.values()))
        return method


# ---------------------------------------------------------------------------
# Coercion from the legacy surface
# ---------------------------------------------------------------------------

_LEGACY_FN_SPECS = {
    _legacy.dftsp: "dftsp",
    _legacy.brute_force: "brute_force",
    _legacy.static_batching: "stb",
    _legacy.no_batching: "nob",
    _legacy.greedy: "greedy",
}


def as_policy(obj: Union[str, SchedulerPolicy, _legacy.Scheduler]
              ) -> SchedulerPolicy:
    """Coerce specs, policy objects, and legacy scheduler callables.

    Known legacy functions map (by identity, not name) to their registered
    policy class so e.g. ``no_batching`` keeps its per-unit oracle; unknown
    callables get the default P1 oracle via ``CallablePolicy``.
    """
    if isinstance(obj, SchedulerPolicy):
        return obj
    if isinstance(obj, str):
        return get_policy(obj)
    if callable(obj):
        known = _LEGACY_FN_SPECS.get(obj)
        return get_policy(known) if known else CallablePolicy(obj)
    raise TypeError(f"cannot build a SchedulerPolicy from {obj!r}")
