"""Unified epoch-protocol metrics (analytic sim AND real-engine serving).

``EpochMetrics`` replaced the two historical records — ``SimResult``
(analytic) and ``ServeTrace`` (real engine) — which disagreed on units.
``throughput`` is requests/second everywhere (the paper's objective).
The deprecated shim modules (``core/epoch.py``, ``serving/simulator.py``)
and their aliases are gone; drive ``EpochRuntime`` directly.

Per-epoch accounting lives in ``traces`` so executor-equivalence tests can
compare scheduling decisions epoch by epoch, not just aggregates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default method) without
    importing numpy for a metrics record; 0.0 on an empty sample."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    pos = (q / 100.0) * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))


@dataclass
class EpochTrace:
    """One epoch of the runtime loop (warmup epochs have counted=False).

    ``quants`` records the quantization method the control plane decided
    for each served model this epoch (``{model_id: method_name}``; the
    ``None`` key on a single-model node) — empty when nothing was served.
    ``wall_s`` is the measured wall-clock of this epoch's
    ``executor.execute`` call — the data plane's real execution time under
    ``EngineExecutor``; under the analytic executor (which charges
    cost-model time and runs nothing) it is just microseconds of Python
    overhead, so use ``tokens_per_s``/``generated_tokens`` (0 for
    analytic) to tell the paths apart, not ``wall_s``.

    Continuous-batching epochs (``ContinuousRuntime``) additionally
    record their segment structure: ``segments`` chunked-decode segments
    ran this epoch, ``occupancy`` is the occupied-slot fraction during
    each of them, ``admitted_mid_epoch`` counts admissions at interior
    segment boundaries (the requests an epoch-boundary protocol would
    have left queued), and ``finished_rids`` the requests whose
    generation COMPLETED this epoch (``selected_rids`` holds admissions).
    All four stay empty/0 under the epoch-boundary runtime.
    """
    epoch: int
    arrived: int
    dropped: int
    selected_rids: List[int]
    truncated: int = 0
    nodes_visited: int = 0
    generated_tokens: int = 0
    counted: bool = True
    quants: Dict[Optional[str], str] = field(default_factory=dict)
    wall_s: float = 0.0
    segments: int = 0
    admitted_mid_epoch: int = 0
    occupancy: List[float] = field(default_factory=list)
    finished_rids: List[int] = field(default_factory=list)
    # KV-block accounting (continuous path, DESIGN.md §2.3): blocks in
    # use after each of this epoch's segments, against the node total.
    # Slot-level for data planes without a physical block pool; true
    # arena pages under the paged engine executor.
    kv_blocks_in_use: List[int] = field(default_factory=list)
    kv_blocks_total: int = 0
    # SLO / robustness accounting (continuous path, DESIGN.md §2.4)
    preempted_rids: List[int] = field(default_factory=list)
    shed_rids: List[int] = field(default_factory=list)
    faults: int = 0               # transient step faults hit this epoch

    @property
    def tokens_per_s(self) -> float:
        """Decode throughput of this epoch's real execution (0 if nothing
        ran or nothing was generated)."""
        return self.generated_tokens / self.wall_s if self.wall_s > 0 else 0.0


@dataclass
class EpochMetrics:
    n_epochs: int
    T_E: float
    served: int = 0
    dropped: int = 0
    arrived: int = 0
    truncated: int = 0            # scheduled but spilled past engine capacity
    generated_tokens: int = 0     # real-engine paths only (0 for analytic)
    wall_s: float = 0.0           # summed execute() wall-clock (counted
                                  # epochs; ~0 but nonzero for analytic)
    batch_sizes: List[int] = field(default_factory=list)
    nodes_visited: int = 0
    leaves_checked: int = 0
    served_by_method: Dict[str, int] = field(default_factory=dict)
    served_by_model: Dict[Optional[str], int] = field(default_factory=dict)
                                  # requests served per hosted model
                                  # (key None on a single-model node) —
                                  # the per-model split the multi-LLM
                                  # benchmarks report
    traces: List[EpochTrace] = field(default_factory=list)
    segments: int = 0             # chunked segments run (continuous path)
    admitted_mid_epoch: int = 0   # admissions at interior segment
                                  # boundaries (continuous path; 0 under
                                  # the epoch-boundary runtime)
    final_queue_rids: List[int] = field(default_factory=list)
                                  # requests still queued when the run
                                  # ended (conservation accounting:
                                  # arrived == served + dropped + queued
                                  # for warmup_epochs=0 runs)
    kv_alloc_tokens: int = 0      # Σ per-segment allocated KV tokens
                                  # (pages_in_use × block_tokens under
                                  # the arena; 0 without block
                                  # accounting)
    kv_dead_tokens: int = 0       # Σ per-segment allocated-but-dead KV
                                  # tokens (junk gaps + reserved tail)
    kv_topup_pages: int = 0       # pages leased via segment-boundary
                                  # lease top-ups (cap-aware incremental
                                  # leasing, DESIGN.md §2.3) this run
    # -- SLO accounting (DESIGN.md §2.4) ------------------------------------
    shed: int = 0                 # load-shed under pressure/quarantine
                                  # (distinct from viability drops)
    preempted: int = 0            # resident rows evicted at a boundary
    resumed: int = 0              # preempted rows re-admitted
    retried: int = 0              # executor step/execute retries after
                                  # transient faults
    slo_met: int = 0              # served requests finishing by deadline
    latencies: List[float] = field(default_factory=list)
                                  # completion - arrival per served req
    ttfts: List[float] = field(default_factory=list)
                                  # first-token time - arrival per served
    tpots: List[float] = field(default_factory=list)
                                  # (completion - first token) / tokens
    in_flight_rids: List[int] = field(default_factory=list)
                                  # resident when the run ENDED — empty
                                  # after a clean drain; populated on the
                                  # partial metrics a DrainStallError
                                  # carries
    # -- fault / degradation accounting -------------------------------------
    faults_injected: int = 0      # transient step faults seen
    watchdog_trips: int = 0       # step calls exceeding the watchdog
    quarantined: List[str] = field(default_factory=list)
                                  # pools quarantined after N consecutive
                                  # step failures
    degraded_segments: int = 0    # segments run in degraded mode
    requanted: int = 0            # LIVE cohorts re-pointed at a degraded
                                  # method on a degradation rising edge
                                  # (mid-flight requant, DESIGN.md §2.4)

    @property
    def throughput(self) -> float:
        """Requests served per second (paper objective) — in BOTH the
        analytic and the real-engine path."""
        return self.served / max(self.n_epochs * self.T_E, 1e-12)

    @property
    def tokens_per_s(self) -> float:
        """Measured decode throughput of the real data plane: generated
        tokens per second of executor wall-clock (0 for analytic runs)."""
        return self.generated_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_batch(self) -> float:
        bs = self.batch_sizes
        return sum(bs) / len(bs) if bs else 0.0

    @property
    def mean_occupancy(self) -> float:
        """Mean occupied-slot fraction across counted continuous-batching
        segments (0.0 under the epoch-boundary runtime)."""
        occ = [o for t in self.traces if t.counted for o in t.occupancy]
        return sum(occ) / len(occ) if occ else 0.0

    @property
    def mean_block_occupancy(self) -> float:
        """Mean KV-blocks-in-use fraction across counted continuous
        segments (DESIGN.md §2.3).  Slot-level (== occupancy) for data
        planes without a block pool; true page occupancy under the
        paged arena — the number ``benchmarks/paged_vs_slab.py`` gates
        against the slab baseline."""
        fracs = [u / t.kv_blocks_total for t in self.traces
                 if t.counted and t.kv_blocks_total
                 for u in t.kv_blocks_in_use]
        return sum(fracs) / len(fracs) if fracs else 0.0

    @property
    def fragmentation(self) -> float:
        """Allocated-but-dead KV tokens over allocated KV tokens (0
        without block accounting): junk-gap and reserved-tail volume
        inside leased pages."""
        return self.kv_dead_tokens / self.kv_alloc_tokens \
            if self.kv_alloc_tokens else 0.0

    # -- SLO views ----------------------------------------------------------

    @property
    def slo_attainment(self) -> float:
        """Fraction of ARRIVED requests served by their deadline — misses,
        drops, and shed work all count against attainment (serving 1 of
        100 on time is not 100% attainment)."""
        return self.slo_met / self.arrived if self.arrived else 0.0

    @property
    def p50_latency(self) -> float:
        return percentile(self.latencies, 50.0)

    @property
    def p99_latency(self) -> float:
        return percentile(self.latencies, 99.0)

    @property
    def p50_ttft(self) -> float:
        return percentile(self.ttfts, 50.0)

    @property
    def p99_ttft(self) -> float:
        return percentile(self.ttfts, 99.0)

    @property
    def mean_tpot(self) -> float:
        return sum(self.tpots) / len(self.tpots) if self.tpots else 0.0

    @property
    def methods_served(self) -> List[str]:
        """Distinct quantization methods that served requests, most-used
        first (adaptive-precision runs list more than one)."""
        return sorted(self.served_by_method,
                      key=lambda k: (-self.served_by_method[k], k))

    # -- ServeTrace compatibility -------------------------------------------

    @property
    def epochs(self) -> int:
        return self.n_epochs

    @property
    def batches(self) -> List[int]:
        return self.batch_sizes

    def row(self) -> Dict[str, float]:
        return {"throughput": self.throughput, "served": self.served,
                "dropped": self.dropped, "arrived": self.arrived,
                "mean_batch": self.mean_batch,
                "nodes": self.nodes_visited}
