"""OFDMA communication model (paper §II-A)."""
from __future__ import annotations

import math
from typing import Sequence

from repro.core.environment import EdgeEnv
from repro.core.request import BITS_PER_TOKEN, Request


def spectral_eff_up(env: EdgeEnv, h: float) -> float:
    """log2(1 + p_u h^2 / (N0 B_U)) — bits/s/Hz on the uplink."""
    return math.log2(1.0 + env.p_u * h * h / (env.N0 * env.B_U))


def spectral_eff_down(env: EdgeEnv, h: float) -> float:
    return math.log2(1.0 + env.p_d * h * h / (env.N0 * env.B_D))


def rate_up(env: EdgeEnv, r: Request, rho: float) -> float:
    return rho * env.B_U * spectral_eff_up(env, r.h)


def rate_down(env: EdgeEnv, r: Request, rho: float) -> float:
    return rho * env.B_D * spectral_eff_down(env, r.h)


def rho_min_up(env: EdgeEnv, r: Request) -> float:
    """Minimum uplink bandwidth fraction so the prompt uploads within T_U."""
    bits = r.s * BITS_PER_TOKEN
    return bits / (env.T_U * env.B_U * spectral_eff_up(env, r.h))


def rho_min_down(env: EdgeEnv, r: Request) -> float:
    """Minimum downlink fraction so the output downloads within T_D."""
    bits = r.n * BITS_PER_TOKEN
    return bits / (env.T_D * env.B_D * spectral_eff_down(env, r.h))


def uplink_feasible(env: EdgeEnv, reqs: Sequence[Request]) -> bool:
    return sum(rho_min_up(env, r) for r in reqs) <= 1.0 + 1e-12


def downlink_feasible(env: EdgeEnv, reqs: Sequence[Request]) -> bool:
    return sum(rho_min_down(env, r) for r in reqs) <= 1.0 + 1e-12
