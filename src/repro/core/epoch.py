"""Epoch-driven simulation of the edge node (paper Fig. 2 + §IV).

Time is divided into epochs of ``T_E`` seconds.  Requests arriving during
epoch e are aggregated and considered for scheduling at the start of epoch
e+1 (their waiting time ``t_w`` = time from arrival to that epoch boundary,
growing by T_E for every epoch they remain queued).  Unscheduled requests
stay in the queue until their deadline can no longer be met, then drop.

``simulate`` runs a scheduler for ``n_epochs`` and reports throughput
(successfully served requests / second — the paper's objective), drops,
batch-size stats and cumulative search-node counts (Table III).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import problem
from repro.core.dftsp import SearchStats
from repro.core.environment import EdgeEnv
from repro.core.request import Request, RequestGenerator
from repro.core.schedulers import Scheduler, get_scheduler, nob_feasible


@dataclass
class SimResult:
    n_epochs: int
    T_E: float
    served: int = 0
    dropped: int = 0
    arrived: int = 0
    batch_sizes: List[int] = field(default_factory=list)
    nodes_visited: int = 0
    leaves_checked: int = 0

    @property
    def throughput(self) -> float:
        """Requests served per second (paper objective, aggregated)."""
        return self.served / (self.n_epochs * self.T_E)

    @property
    def mean_batch(self) -> float:
        bs = self.batch_sizes
        return sum(bs) / len(bs) if bs else 0.0

    def row(self) -> Dict[str, float]:
        return {"throughput": self.throughput, "served": self.served,
                "dropped": self.dropped, "arrived": self.arrived,
                "mean_batch": self.mean_batch,
                "nodes": self.nodes_visited}


def _still_viable(env: EdgeEnv, r: Request, now: float) -> bool:
    """Could this queued request still meet its deadline if scheduled at the
    *next* epoch boundary?  Lower bound: comm slots + its lone compute at
    its true prompt length (<= any batched/padded execution)."""
    t_w = now - r.arrival
    cm = env.cost_model()
    lone = env.quant.beta * (cm.prefill_flops(r.s, 1)
                             + cm.decode_flops(r.s, [r.n])) / env.C
    return t_w + env.T_U + lone + env.T_D <= r.tau + 1e-12


def simulate(env: EdgeEnv, scheduler: str | Scheduler,
             rate: float, n_epochs: int = 30, seed: int = 0,
             gen: Optional[RequestGenerator] = None,
             warmup_epochs: int = 1) -> SimResult:
    """Run the epoch protocol with Poisson(rate) arrivals."""
    sched = get_scheduler(scheduler) if isinstance(scheduler, str) else scheduler
    gen = gen or RequestGenerator(rate=rate, seed=seed,
                                  lengths=(128, 256, 512))
    res = SimResult(n_epochs=n_epochs, T_E=env.T_E)
    queue: List[Request] = []

    for e in range(n_epochs + warmup_epochs):
        t0, t1 = e * env.T_E, (e + 1) * env.T_E
        counting = e >= warmup_epochs
        # requests that arrived during the previous epoch join the queue
        arrivals = gen.within(t0 - env.T_E, t0) if e else []
        if counting:
            res.arrived += len(arrivals)
        queue.extend(arrivals)

        # age the queue; drop hopeless requests
        viable: List[Request] = []
        for r in queue:
            r.t_w = t0 - r.arrival
            if _still_viable(env, r, t0):
                viable.append(r)
            elif counting:
                res.dropped += 1
        queue = viable

        sel, stats = sched(env, queue)
        # authoritative feasibility recheck (schedulers must not cheat);
        # NoB is per-unit (no batch), all others must satisfy P1.
        is_nob = scheduler == "nob" or getattr(sched, "__name__", "") == \
            "no_batching"
        ok = nob_feasible(env, sel) if is_nob else problem.feasible(env, sel)
        assert ok, f"{scheduler} returned an infeasible batch"
        if counting:
            res.served += len(sel)
            res.batch_sizes.append(len(sel))
            res.nodes_visited += stats.nodes_visited
            res.leaves_checked += stats.leaves_checked
        chosen = {r.rid for r in sel}
        queue = [r for r in queue if r.rid not in chosen]
    return res


def sweep(env: EdgeEnv, schedulers: List[str], rates: List[float],
          n_epochs: int = 20, seed: int = 0) -> Dict[str, List[SimResult]]:
    """Throughput-vs-arrival-rate sweep (paper Fig. 5a driver)."""
    out: Dict[str, List[SimResult]] = {s: [] for s in schedulers}
    for s in schedulers:
        for rate in rates:
            out[s].append(simulate(env, s, rate, n_epochs=n_epochs,
                                   seed=seed))
    return out
