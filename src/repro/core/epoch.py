"""Analytic epoch simulation — deprecation shims over the unified runtime.

The epoch/queue lifecycle (paper Fig. 2 + §IV) lives in exactly one
place now: ``repro.serving.runtime.EpochRuntime``, parameterized by a
``SchedulerPolicy`` (control plane) and an ``Executor`` (data plane).
``simulate`` / ``sweep`` below are thin shims that pair a policy with the
``AnalyticExecutor`` — they keep every historical figure driver working
and return the unified ``EpochMetrics`` (of which ``SimResult`` is a
deprecated alias; throughput is requests/second, the paper's objective).

Prefer the runtime directly in new code::

    from repro.core.policy import get_policy
    from repro.serving.runtime import AnalyticExecutor, EpochRuntime

    metrics = EpochRuntime(env, get_policy("dftsp"),
                           AnalyticExecutor()).run(rate=25, n_epochs=30)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

from repro.core.environment import EdgeEnv
from repro.core.metrics import EpochMetrics
from repro.core.policy import SchedulerPolicy
from repro.core.request import RequestGenerator
from repro.core.schedulers import Scheduler
from repro.serving.runtime import AnalyticExecutor, EpochRuntime, still_viable

# Deprecated aliases (pre-redesign names).
SimResult = EpochMetrics
_still_viable = still_viable


def simulate(env: EdgeEnv,
             scheduler: Union[str, Scheduler, SchedulerPolicy],
             rate: float, n_epochs: int = 30, seed: int = 0,
             gen: Optional[RequestGenerator] = None,
             warmup_epochs: int = 1) -> EpochMetrics:
    """Deprecated shim: run the epoch protocol analytically (cost-model
    time only).  Delegates to ``EpochRuntime`` + ``AnalyticExecutor``."""
    runtime = EpochRuntime(env, scheduler, AnalyticExecutor())
    return runtime.run(rate=rate, n_epochs=n_epochs, seed=seed, gen=gen,
                       warmup_epochs=warmup_epochs)


def sweep(env: EdgeEnv, schedulers: List[str], rates: List[float],
          n_epochs: int = 20, seed: int = 0) -> Dict[str, List[EpochMetrics]]:
    """Deprecated shim: throughput-vs-arrival-rate sweep (Fig. 5a)."""
    out: Dict[str, List[EpochMetrics]] = {s: [] for s in schedulers}
    for s in schedulers:
        for rate in rates:
            out[s].append(simulate(env, s, rate, n_epochs=n_epochs,
                                   seed=seed))
    return out
