"""Problem P1 / P2 (paper §II-C, §III-A): constraint oracles and the
k-coefficient reformulation.

P1:  max |S|  s.t.
  (1a) sum rho_min_up  <= 1         (1b) sum rho_min_down <= 1
  (1c) alpha (m1 + m2_I + m2_A) <= M
  (1d) t_w,i + T_U + beta (t_I + t_A) + T_D <= tau_i   for all i in S
  (1e) a_i <= f(dPPL)

Every oracle takes an explicit ``quant`` (the method the control plane
decided for this batch); ``quant=None`` falls back to the environment's
deployed method, which keeps fixed-method callers bit-identical.  This is
what lets DFTSP treat the quantization method as a decision variable
instead of a frozen deployment constant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core import comm
from repro.core.environment import EdgeEnv
from repro.core.quantization import QuantMethod, f_accuracy
from repro.core.request import Request


def accuracy_feasible(env: EdgeEnv, r: Request,
                      quant: Optional[QuantMethod] = None) -> bool:
    q = quant or env.quant
    return r.a <= f_accuracy(q.delta_ppl(env.model.arch_id)) + 1e-12


def filter_accuracy(env: EdgeEnv, reqs: Sequence[Request],
                    quant: Optional[QuantMethod] = None) -> List[Request]:
    """The paper's I-tilde: requests satisfied with the quantized model."""
    return [r for r in reqs if accuracy_feasible(env, r, quant)]


def memory_used(env: EdgeEnv, reqs: Sequence[Request],
                quant: Optional[QuantMethod] = None) -> float:
    cm = env.cost_model()
    q = quant or env.quant
    m1 = cm.weight_bytes()
    m2i = cm.kv_bytes_prefill(env.s_max, len(reqs))
    m2a = cm.kv_bytes_decode([r.n for r in reqs], env.s_max)
    return q.alpha_w * m1 + q.alpha_a * (m2i + m2a)


def memory_feasible(env: EdgeEnv, reqs: Sequence[Request],
                    quant: Optional[QuantMethod] = None) -> bool:
    return memory_used(env, reqs, quant) <= env.M + 1e-6


def batch_compute_time(env: EdgeEnv, reqs: Sequence[Request],
                       quant: Optional[QuantMethod] = None) -> float:
    """beta (t_I + t_A) for the whole batch (paper's aggregate-FLOPs model)."""
    cm = env.cost_model()
    q = quant or env.quant
    t_i = cm.t_prefill(env.s_max, len(reqs), env.C)
    t_a = cm.t_decode(env.s_max, [r.n for r in reqs], env.C)
    return q.beta * (t_i + t_a)


def latency_feasible(env: EdgeEnv, reqs: Sequence[Request],
                     t_compute: Optional[float] = None,
                     quant: Optional[QuantMethod] = None,
                     t_extra: float = 0.0) -> bool:
    """(1d): every scheduled request meets its deadline.

    ``t_extra`` is serial epoch time spent BEFORE this batch computes —
    an earlier sub-batch's compute plus the weight-swap latency when the
    epoch's queue is split across quantization methods (DESIGN.md §1.1).
    The default 0.0 is the paper's one-batch-per-epoch accounting.
    """
    if not reqs:
        return True
    if t_compute is None:
        t_compute = batch_compute_time(env, reqs, quant)
    slack = min(r.tau - r.t_w for r in reqs)
    return env.T_U + t_extra + t_compute + env.T_D <= slack + 1e-12


def feasible(env: EdgeEnv, reqs: Sequence[Request],
             check_accuracy: bool = True,
             quant: Optional[QuantMethod] = None) -> bool:
    """Full P1 feasibility of a candidate batch (constraints 1a-1e)."""
    if check_accuracy and not all(accuracy_feasible(env, r, quant)
                                  for r in reqs):
        return False
    return (comm.uplink_feasible(env, reqs)
            and comm.downlink_feasible(env, reqs)
            and memory_feasible(env, reqs, quant)
            and latency_feasible(env, reqs, quant=quant))


def split_feasible(env: EdgeEnv,
                   subs: Sequence[tuple],
                   swap_record: Optional[dict] = None,
                   t_extra: float = 0.0,
                   rho_u0: float = 0.0, rho_d0: float = 0.0) -> bool:
    """P1 feasibility of a SPLIT epoch: ``subs`` is a sequence of
    ``(batch, quant)`` sub-batches served sequentially within one epoch,
    each at its own quantization method (DESIGN.md §1.1).

    * comm (1a/1b) is joint — every sub-batch's transfers share the
      epoch's OFDMA budget (``rho_*0`` lets multi-LLM callers charge
      spectrum other models already hold);
    * accuracy (1e) and memory (1c) are per-sub-batch at its OWN method —
      sub-batches execute back to back, so a sub-batch's KV is released
      before the next one allocates (peak, not sum);
    * latency (1d) is serial: sub-batch j waits through every earlier
      sub-batch's compute plus the measured weight-swap latency between
      consecutive methods (``quantization.swap_seconds``; ``t_extra``
      seats the whole split behind already-queued compute).
    """
    from repro.core.quantization import swap_seconds
    subs = [(list(b), q) for b, q in subs if b]
    flat = [r for b, _ in subs for r in b]
    if not flat:
        return True
    rho_u = rho_u0 + sum(comm.rho_min_up(env, r) for r in flat)
    rho_d = rho_d0 + sum(comm.rho_min_down(env, r) for r in flat)
    if rho_u > 1.0 + 1e-9 or rho_d > 1.0 + 1e-9:
        return False
    t_ahead = t_extra
    prev_q = None
    for batch, q in subs:
        if not all(accuracy_feasible(env, r, q) for r in batch):
            return False
        if not memory_feasible(env, batch, q):
            return False
        if prev_q is not None:
            t_ahead += swap_seconds(swap_record, prev_q, q)
        if not latency_feasible(env, batch, quant=q, t_extra=t_ahead):
            return False
        t_ahead += batch_compute_time(env, batch, quant=q)
        prev_q = q
    return True


# ---------------------------------------------------------------------------
# P2 k-coefficients (paper §III-A) — used by DFTSP's sort keys and by tests
# that verify the reformulation matches the direct constraint oracles.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class P2Coefficients:
    """tau_tilde_i = (tau_i - t_w,i - T_U - T_D - extra_s) * C / beta - k3 z ;
    M_tilde = k2 - s' z  (in KV-token units).

    ``extra_s`` is the swap-cost term of the split-epoch extension: serial
    seconds already spent in this epoch before this batch's compute starts
    (earlier differently-quantized sub-batches plus the measured weight-swap
    latency between their methods).  It enters the slack the same way T_U
    does — every request in this sub-batch waits through it — so the
    slack ranking and the descent's bounds price splits consistently with
    the authoritative oracle (``latency_feasible(..., t_extra=extra_s)``).
    """
    env: EdgeEnv
    quant: Optional[QuantMethod] = None
    extra_s: float = 0.0

    @property
    def q(self) -> QuantMethod:
        return self.quant or self.env.quant

    def tau_tilde(self, r: Request, z: int) -> float:
        """Latency slack in FLOP units, net of the per-request prefill cost
        (k3 = prefill FLOPs per prompt)."""
        env = self.env
        cm = env.cost_model()
        k3 = cm.prefill_flops(env.s_max, 1)
        slack_flops = ((r.tau - r.t_w - env.T_U - env.T_D - self.extra_s)
                       * env.C / self.q.beta)
        return slack_flops - k3 * z

    def decode_cost(self, r: Request) -> float:
        """k4 n + k5 n^2 equivalent: this request's decode FLOPs."""
        return self.env.cost_model().decode_flops(self.env.s_max, [r.n])

    def memory_budget_tokens(self, z: int) -> float:
        """M_tilde: KV-token capacity left after weights + z prefill caches."""
        env = self.env
        cm = env.cost_model()
        q = self.q
        per_tok = cm._kv_bytes_per_token() * q.alpha_a
        if per_tok <= 0:
            return float("inf")
        left = (env.M - q.alpha_w * cm.weight_bytes()
                - q.alpha_a * cm.kv_bytes_prefill(env.s_max, 1) * z)
        return left / per_tok
