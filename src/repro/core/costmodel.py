"""Analytic inference cost model (paper §II-B), generalized per family.

The paper derives, for MHA dense transformers with 2-byte params:

  m1    = L (8 dm dh nh + 4 dm df)                      [weight bytes]
  m2_I  = 4 L s' dm * batch                             [prefill KV bytes]
  m2_A  = 4 L n_i dm * x_i (summed)                     [decode KV bytes]
  t_I   = (L*batch/C) (6 s' dm^2 + 4 s'^2 dm + 2 s' dm^2 + 4 s' dm df)
  t_A   = (L/C) sum_i (n_i-1)(6 dm^2 + 4(s'+n_i/2) dm + 2 dm^2 + 4 dm df)

``CostModel`` reproduces these exactly for MHA dense archs (kv=nh) and
generalizes to GQA / MoE / SSM / hybrid / enc-dec (DESIGN.md §4):
  * GQA: K/V projections & cache scale by nkv/nh;
  * MoE: FFN terms use top_k active experts (+ router), weights count all;
  * SSM/xLSTM: O(1)-in-context state instead of KV cache; decode FLOPs have
    no (s' + n/2) attention-read term => latency constraint becomes linear;
  * SWA: attention reads min(context, window); KV cache capped at window;
  * enc-dec: prefill includes the encoder pass; cross-attn KV is static.

All byte quantities are *pre-quantization* (2-byte params), matching the
paper; quantization enters via alpha/beta in problem.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.config import ModelConfig

PARAM_BYTES = 2.0


@dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    paper_faithful: bool = False   # force the paper's MHA equations

    # -- memory ------------------------------------------------------------

    def weight_bytes(self) -> float:
        """m1.  Paper form for MHA dense; param_count elsewhere."""
        c = self.cfg
        if self._mha_dense():
            return c.n_layers * (8 * c.d_model * c.d_head * c.n_heads
                                 + 4 * c.d_model * c.d_ff) * (PARAM_BYTES / 2)
        return c.param_count() * PARAM_BYTES

    def _kv_bytes_per_token(self) -> float:
        """K+V bytes per token per layer stack (GQA-aware)."""
        c = self.cfg
        if c.family == "ssm":
            return 0.0
        if c.family == "hybrid":
            # only the shared-attn sites cache KV
            n_sites = c.n_layers // c.hybrid.attn_every
            return 2 * PARAM_BYTES * n_sites * c.n_kv_heads * c.d_head
        return 2 * PARAM_BYTES * c.n_layers * c.n_kv_heads * c.d_head

    def state_bytes(self) -> float:
        """O(1) recurrent state per sequence (SSM/hybrid; 0 otherwise)."""
        c = self.cfg
        if c.family == "ssm" and c.xlstm is not None:
            d_in = int(c.xlstm.proj_factor_mlstm * c.d_model)
            dh = d_in // c.n_heads
            per_mlstm = c.n_heads * dh * dh * 4          # f32 C matrix
            return c.n_layers * per_mlstm
        if c.family in ("ssm", "hybrid"):
            d_inner = c.ssm.expand * c.d_model
            H = d_inner // c.ssm.head_dim
            return c.n_layers * H * c.ssm.head_dim * c.ssm.d_state * 4
        return 0.0

    def _ctx(self, length: int) -> float:
        """Effective cached context (window-capped)."""
        w = self.cfg.sliding_window
        return float(min(length, w)) if w else float(length)

    def kv_bytes_prefill(self, s: int, batch: int) -> float:
        """m2_I for ``batch`` prompts of padded length s."""
        return (self._kv_bytes_per_token() * self._ctx(s)
                + self.state_bytes()) * batch

    def kv_bytes_decode(self, ns: Sequence[int], s: int = 0) -> float:
        """m2_A: additional KV for each request's n_i output tokens.

        With a sliding window the cache is a rolling buffer of capacity W,
        so decode only grows it by the slots not already used by the prompt.
        """
        per_tok = self._kv_bytes_per_token()
        w = self.cfg.sliding_window
        if w:
            return sum(per_tok * max(0, min(s + n, w) - min(s, w))
                       for n in ns)
        return sum(per_tok * n for n in ns)

    # -- FLOPs / latency -----------------------------------------------------

    def _ffn_flops_per_token(self) -> float:
        c = self.cfg
        if c.family == "ssm":
            return 0.0
        n_mats = 3 if c.act == "silu" else 2
        per = n_mats * 2 * c.d_model * c.d_ff
        if c.is_moe:
            return c.moe.top_k * per + 2 * c.d_model * c.moe.n_experts
        return per

    def _qkvo_flops_per_token(self) -> float:
        c = self.cfg
        q = 2 * c.d_model * c.n_heads * c.d_head
        kv = 2 * 2 * c.d_model * c.n_kv_heads * c.d_head
        o = 2 * c.n_heads * c.d_head * c.d_model
        return q + kv + o

    def _attn_read_flops(self, ctx: float) -> float:
        """QK^T + PV per token at context ``ctx``."""
        c = self.cfg
        return 4 * self._ctx(ctx) * c.n_heads * c.d_head

    def _ssm_flops_per_token(self) -> float:
        c = self.cfg
        if c.family == "ssm" and c.xlstm is not None:
            d_in = int(c.xlstm.proj_factor_mlstm * c.d_model)
            dh = d_in // c.n_heads
            proj = 2 * (2 * c.d_model * d_in + d_in * c.d_model
                        + 3 * d_in * d_in)
            cell = 2 * c.n_heads * dh * dh * 2           # C update + read
            return proj + cell
        d_inner = c.ssm.expand * c.d_model
        H = d_inner // c.ssm.head_dim
        proj = 2 * (c.d_model * (2 * d_inner + 2 * c.ssm.d_state + H)
                    + d_inner * c.d_model)
        cell = 2 * H * c.ssm.head_dim * c.ssm.d_state * 2
        return proj + cell

    def _layer_flops_per_token(self, ctx: float) -> float:
        """One decoder layer, one token, at effective context ctx."""
        c = self.cfg
        if c.family == "ssm":
            return self._ssm_flops_per_token()
        if c.family == "hybrid":
            # per *average* layer: mamba every layer + shared attn at sites
            site_frac = (c.n_layers // c.hybrid.attn_every) / c.n_layers
            attn = (self._qkvo_flops_per_token()
                    + self._attn_read_flops(min(ctx, 4096))
                    + self._ffn_flops_per_token())
            return self._ssm_flops_per_token() + site_frac * attn
        return (self._qkvo_flops_per_token() + self._attn_read_flops(ctx)
                + self._ffn_flops_per_token())

    def prefill_flops(self, s: int, batch: int) -> float:
        """Total FLOPs of the Initial Stage for a batch of padded length s."""
        c = self.cfg
        if self._mha_dense():
            dm, df, L = c.d_model, c.d_ff, c.n_layers
            per_prompt = L * (6 * s * dm * dm + 4 * s * s * dm
                              + 2 * s * dm * dm + 4 * s * dm * df)
            return per_prompt * batch
        # general: sum over positions of per-token cost at causal context
        if c.family == "ssm":
            per_prompt = c.n_layers * s * self._ssm_flops_per_token()
        else:
            avg_ctx = (s + 1) / 2.0
            per_prompt = c.n_layers * s * self._layer_flops_per_token(avg_ctx)
        if c.family == "audio":
            F = c.encdec.n_audio_frames
            enc = c.encdec.n_enc_layers * F * (
                self._qkvo_flops_per_token() + self._attn_read_flops(F)
                + self._ffn_flops_per_token())
            cross = c.n_layers * s * (self._qkvo_flops_per_token()
                                      + self._attn_read_flops(F))
            per_prompt += enc + cross
        return per_prompt * batch

    def decode_flops(self, s: int, ns: Sequence[int]) -> float:
        """Total FLOPs of the Auto-regressive Stage (paper's t_A * C)."""
        c = self.cfg
        total = 0.0
        for n in ns:
            iters = max(n - 1, 0)
            if self._mha_dense():
                dm, df, L = c.d_model, c.d_ff, c.n_layers
                total += L * iters * (6 * dm * dm + 4 * (s + n / 2.0) * dm
                                      + 2 * dm * dm + 4 * dm * df)
            else:
                avg_ctx = s + n / 2.0
                per_tok = c.n_layers * self._layer_flops_per_token(avg_ctx)
                if c.family == "audio":
                    per_tok += c.n_layers * (
                        self._qkvo_flops_per_token()
                        + self._attn_read_flops(c.encdec.n_audio_frames))
                total += iters * per_tok
        return total

    def t_prefill(self, s: int, batch: int, C: float) -> float:
        return self.prefill_flops(s, batch) / C

    def t_decode(self, s: int, ns: Sequence[int], C: float) -> float:
        return self.decode_flops(s, ns) / C

    # -- helpers -------------------------------------------------------------

    def _mha_dense(self) -> bool:
        c = self.cfg
        return (self.paper_faithful or
                (c.family == "dense" and c.n_kv_heads == c.n_heads
                 and c.act != "silu" and not c.sliding_window))

    def latency_is_quadratic(self) -> bool:
        """Whether t_A grows ~ n^2 (attention read over growing context)."""
        return self.cfg.family not in ("ssm",) and not self.cfg.sliding_window
