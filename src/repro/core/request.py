"""User inference requests  <s_i, n_i, tau_i, a_i>  (paper §II)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

BYTES_PER_TOKEN = 2       # BPE token index, 2-byte (paper §IV)
BITS_PER_TOKEN = 16


@dataclass
class Request:
    rid: int
    s: int                 # input prompt length (tokens)
    n: int                 # maximum output length (tokens), one of the levels
    tau: float             # latency requirement (seconds)
    a: float               # required accuracy (in [0,1]; needs a <= f(dPPL))
    h: float               # channel gain (amplitude)
    arrival: float = 0.0   # arrival time (seconds)
    t_w: float = 0.0       # waiting time at scheduling (seconds)
    model_id: Optional[str] = None   # hosted model this request targets
                                     # (None on a single-LLM node)


@dataclass
class RequestGenerator:
    """Poisson arrivals with the paper's §IV marginals."""
    rate: float                            # requests / second
    lengths: tuple = (128, 256, 512)       # input & output token levels
    tau_range: tuple = (0.5, 2.0)
    acc_range: tuple = (0.0, 1.0)
    path_loss: float = 1e-3                # Rayleigh fading scale (power)
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False, default=None)
    _next_id: int = field(init=False, default=0)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def within(self, t0: float, t1: float) -> list:
        """Generate arrivals in [t0, t1)."""
        rng = self._rng
        n = rng.poisson(self.rate * (t1 - t0))
        times = np.sort(rng.uniform(t0, t1, size=n))
        out = []
        for t in times:
            # Rayleigh amplitude with E[h^2] = path_loss
            h = float(rng.rayleigh(scale=np.sqrt(self.path_loss / 2.0)))
            out.append(Request(
                rid=self._next_id,
                s=int(rng.choice(self.lengths)),
                n=int(rng.choice(self.lengths)),
                tau=float(rng.uniform(*self.tau_range)),
                a=float(rng.uniform(*self.acc_range)),
                h=h,
                arrival=float(t)))
            self._next_id += 1
        return out


@dataclass
class ReplayGenerator:
    """Replays a FROZEN arrival stream through the ``within`` interface.

    Lets two runtimes that slice time differently (the epoch-boundary
    loop queries whole epochs, the continuous loop queries segment
    windows) see the IDENTICAL traffic realization — the like-for-like
    requirement of the continuous-vs-epoch comparison.  Each ``within``
    call returns fresh copies, so runs never share mutable Request state
    (``t_w``/``model_id``).
    """
    requests: Sequence[Request]

    @classmethod
    def poisson(cls, rate: float, horizon: float, seed: int = 0,
                **kw) -> "ReplayGenerator":
        """Freeze one Poisson stream over ``[0, horizon)``."""
        gen = RequestGenerator(rate=rate, seed=seed, **kw)
        return cls(requests=gen.within(0.0, horizon))

    def within(self, t0: float, t1: float) -> list:
        return [dataclasses.replace(r) for r in self.requests
                if t0 <= r.arrival < t1]
