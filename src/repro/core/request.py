"""User inference requests  <s_i, n_i, tau_i, a_i>  (paper §II)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

BYTES_PER_TOKEN = 2       # BPE token index, 2-byte (paper §IV)
BITS_PER_TOKEN = 16


@dataclass
class Request:
    rid: int
    s: int                 # input prompt length (tokens)
    n: int                 # maximum output length (tokens), one of the levels
    tau: float             # latency requirement (seconds)
    a: float               # required accuracy (in [0,1]; needs a <= f(dPPL))
    h: float               # channel gain (amplitude)
    arrival: float = 0.0   # arrival time (seconds)
    t_w: float = 0.0       # waiting time at scheduling (seconds)
    model_id: Optional[str] = None   # hosted model this request targets
                                     # (None on a single-LLM node)
    priority: int = 0      # SLO priority class (larger = more important;
                           # EDF orders within a class, and preemption
                           # only ever evicts a strictly lower class)

    @property
    def deadline(self) -> float:
        """Absolute completion deadline: the paper's per-user latency
        constraint (1d) anchored at arrival."""
        return self.arrival + self.tau


@dataclass
class RequestGenerator:
    """Poisson arrivals with the paper's §IV marginals.

    ``priorities`` optionally assigns each arrival an SLO priority class
    (uniform over the levels).  The default single level draws NOTHING
    from the rng, so pre-SLO streams stay bit-identical.
    """
    rate: float                            # requests / second
    lengths: tuple = (128, 256, 512)       # input & output token levels
    tau_range: tuple = (0.5, 2.0)
    acc_range: tuple = (0.0, 1.0)
    path_loss: float = 1e-3                # Rayleigh fading scale (power)
    seed: int = 0
    priorities: tuple = (0,)               # SLO priority levels to sample
    _rng: np.random.Generator = field(init=False, repr=False, default=None)
    _next_id: int = field(init=False, default=0)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def within(self, t0: float, t1: float) -> list:
        """Generate arrivals in [t0, t1)."""
        rng = self._rng
        n = rng.poisson(self.rate * (t1 - t0))
        times = np.sort(rng.uniform(t0, t1, size=n))
        out = []
        for t in times:
            # Rayleigh amplitude with E[h^2] = path_loss
            h = float(rng.rayleigh(scale=np.sqrt(self.path_loss / 2.0)))
            out.append(Request(
                rid=self._next_id,
                s=int(rng.choice(self.lengths)),
                n=int(rng.choice(self.lengths)),
                tau=float(rng.uniform(*self.tau_range)),
                a=float(rng.uniform(*self.acc_range)),
                h=h,
                arrival=float(t),
                priority=int(rng.choice(self.priorities))
                if len(self.priorities) > 1 else int(self.priorities[0])))
            self._next_id += 1
        return out


@dataclass
class ReplayGenerator:
    """Replays a FROZEN arrival stream through the ``within`` interface.

    Lets two runtimes that slice time differently (the epoch-boundary
    loop queries whole epochs, the continuous loop queries segment
    windows) see the IDENTICAL traffic realization — the like-for-like
    requirement of the continuous-vs-epoch comparison.  Each ``within``
    call returns fresh copies, so runs never share mutable Request state
    (``t_w``/``model_id``).
    """
    requests: Sequence[Request]

    @classmethod
    def poisson(cls, rate: float, horizon: float, seed: int = 0,
                **kw) -> "ReplayGenerator":
        """Freeze one Poisson stream over ``[0, horizon)``."""
        gen = RequestGenerator(rate=rate, seed=seed, **kw)
        return cls(requests=gen.within(0.0, horizon))

    def within(self, t0: float, t1: float) -> list:
        return [dataclasses.replace(r) for r in self.requests
                if t0 <= r.arrival < t1]


@dataclass
class BurstyGenerator:
    """Bursty/diurnal arrivals: a non-homogeneous Poisson process, FROZEN
    at construction and replayed through ``within`` — the same
    freeze-and-replay contract as :class:`ReplayGenerator`, so the
    epoch-boundary and continuous protocols (which slice time
    differently) see the IDENTICAL bursty traffic realization.

    The instantaneous rate is the base rate modulated by a diurnal
    sinusoid plus rectangular burst windows::

        rate(t) = base_rate * (1 + depth * sin(2*pi*t / period))
                            * mult(t)        # mult from overlapping bursts

    with ``bursts`` a sequence of ``(t_start, duration, multiplier)``.
    The stream is drawn by thinning a homogeneous process at the peak
    rate, so the SAME parameters always freeze the SAME stream — the
    determinism the SLO benchmark's committed artifact relies on.
    Marginals (lengths, tau, accuracy, fading, priorities) follow
    :class:`RequestGenerator`.
    """
    base_rate: float
    horizon: float
    seed: int = 0
    period: float = 16.0
    depth: float = 0.5
    bursts: tuple = ()                     # ((t_start, duration, mult), ...)
    lengths: tuple = (128, 256, 512)
    tau_range: tuple = (0.5, 2.0)
    acc_range: tuple = (0.0, 1.0)
    path_loss: float = 1e-3
    priorities: tuple = (0,)
    requests: list = field(init=False, repr=False, default=None)

    def rate_at(self, t: float) -> float:
        mult = 1.0
        for t0, dur, m in self.bursts:
            if t0 <= t < t0 + dur:
                mult *= m
        return self.base_rate * (1.0 + self.depth
                                 * np.sin(2.0 * np.pi * t / self.period)) \
            * mult

    def _peak_rate(self) -> float:
        peak_mult = 1.0
        for _, _, m in self.bursts:
            peak_mult = max(peak_mult, peak_mult * max(1.0, m))
        return self.base_rate * (1.0 + abs(self.depth)) * peak_mult

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        lam = self._peak_rate()
        n = rng.poisson(lam * self.horizon)
        times = np.sort(rng.uniform(0.0, self.horizon, size=n))
        keep = rng.uniform(size=n)          # thinning draws, one per point
        self.requests = []
        rid = 0
        for t, u in zip(times, keep):
            if u * lam > self.rate_at(float(t)):
                continue
            h = float(rng.rayleigh(scale=np.sqrt(self.path_loss / 2.0)))
            self.requests.append(Request(
                rid=rid,
                s=int(rng.choice(self.lengths)),
                n=int(rng.choice(self.lengths)),
                tau=float(rng.uniform(*self.tau_range)),
                a=float(rng.uniform(*self.acc_range)),
                h=h,
                arrival=float(t),
                priority=int(rng.choice(self.priorities))))
            rid += 1

    def within(self, t0: float, t1: float) -> list:
        return [dataclasses.replace(r) for r in self.requests
                if t0 <= r.arrival < t1]
