"""Multi-LLM edge node (paper §II: "while Fig. 1 focuses on one LLM, our
approach is adaptable for multiple LLMs").

The EN hosts M quantized models sharing one memory pool, one compute
budget and one OFDMA spectrum; each request targets a model
(``Request.model_id`` via the ``tag`` trick below).  Within an epoch the
scheduled batches execute sequentially in a fixed model order, so a
request's latency includes every earlier model's batch compute (faithful
to the single-compute-slot protocol of Fig. 2).

``multi_dftsp`` schedules jointly: models are visited in
shortest-batch-first order and each runs the paper's DFTSP against the
RESIDUAL budgets (memory already committed by earlier models, bandwidth
fractions consumed, compute time already queued).  This is a
beyond-paper heuristic — per-model DFTSP is optimal for its residual
subproblem, but the joint selection is not proven optimal (the joint
problem adds knapsack coupling across models; see EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core import comm, problem
from repro.core.dftsp import SearchStats, dftsp_schedule
from repro.core.environment import EdgeEnv
from repro.core.request import Request


@dataclass(frozen=True)
class MultiLLMEnv:
    """Shared edge node hosting several (model, quant) deployments."""
    envs: Dict[str, EdgeEnv]          # model_id -> single-model view
    C: float                          # shared compute (FLOP/s)
    M: float                          # shared memory (bytes)

    @classmethod
    def host(cls, envs: Dict[str, EdgeEnv]) -> "MultiLLMEnv":
        any_env = next(iter(envs.values()))
        return cls(envs={k: v.with_(C=any_env.C, M=any_env.M)
                         for k, v in envs.items()},
                   C=any_env.C, M=any_env.M)

    def weight_bytes(self) -> float:
        """Resident weights of every hosted model (always in memory)."""
        return sum(e.quant.alpha_w * e.cost_model().weight_bytes()
                   for e in self.envs.values())


def tag(requests: Sequence[Request], model_id: str) -> List[Request]:
    """Mark requests as targeting one hosted model."""
    for r in requests:
        r.model_id = model_id          # type: ignore[attr-defined]
    return list(requests)


def _kv_bytes(env: EdgeEnv, batch: Sequence[Request]) -> float:
    cm = env.cost_model()
    return env.quant.alpha_a * (
        cm.kv_bytes_prefill(env.s_max, len(batch))
        + cm.kv_bytes_decode([r.n for r in batch], env.s_max))


def multi_dftsp(menv: MultiLLMEnv, requests: Sequence[Request]
                ) -> Tuple[Dict[str, List[Request]], SearchStats]:
    """Joint schedule across hosted models on shared budgets."""
    stats = SearchStats()
    by_model: Dict[str, List[Request]] = {m: [] for m in menv.envs}
    for r in requests:
        mid = getattr(r, "model_id", None)
        if mid in by_model:
            by_model[mid].append(r)

    # cheapest-expected-batch model first (its requests lose the least
    # slack to queueing behind other models' compute)
    order = sorted(menv.envs,
                   key=lambda m: menv.envs[m].cost_model().weight_bytes())

    mem_left = menv.M - menv.weight_bytes()
    if mem_left < 0:
        return {m: [] for m in menv.envs}, stats
    rho_u_left = rho_d_left = 1.0
    t_queued = 0.0
    out: Dict[str, List[Request]] = {}

    for mid in order:
        env = menv.envs[mid]
        pool = by_model[mid]
        # residual-budget view: memory = own weights + the shared
        # remainder (dftsp's (1c) re-subtracts the own-weight term), and
        # earlier models' batch compute delays this batch exactly like a
        # longer uplink slot (single compute queue, Fig. 2)
        own_w = env.quant.alpha_w * env.cost_model().weight_bytes()
        res_env = env.with_(M=own_w + max(mem_left, 0.0),
                            T_U=env.T_U + t_queued)
        sel, st = dftsp_schedule(res_env, pool)
        stats.nodes_visited += st.nodes_visited
        stats.leaves_checked += st.leaves_checked

        # enforce the SHARED bandwidth budget (dftsp saw a full link)
        kept: List[Request] = []
        for r in sorted(sel, key=lambda r: comm.rho_min_up(env, r)):
            ru, rd = comm.rho_min_up(env, r), comm.rho_min_down(env, r)
            if ru <= rho_u_left and rd <= rho_d_left:
                kept.append(r)
                rho_u_left -= ru
                rho_d_left -= rd
        while kept and not problem.latency_feasible(res_env, kept):
            kept.pop()                 # drop the tightest-slack members
        out[mid] = kept
        if kept:
            mem_left -= _kv_bytes(env, kept)
            t_queued += problem.batch_compute_time(env, kept)
    stats.z_solved = sum(len(v) for v in out.values())
    return out, stats
