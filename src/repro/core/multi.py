"""Multi-LLM edge node (paper §II: "while Fig. 1 focuses on one LLM, our
approach is adaptable for multiple LLMs").

The EN hosts M quantized models sharing one memory pool, one compute
budget and one OFDMA spectrum; each request targets a model via the
``Request.model_id`` field (``tag`` is a convenience).  Within an epoch the
scheduled batches execute sequentially in a fixed model order, so a
request's latency includes every earlier model's batch compute (faithful
to the single-compute-slot protocol of Fig. 2).

``multi_dftsp`` schedules jointly: models are visited in a configurable
order (cheapest-weights first by default) and each runs the paper's DFTSP against the
RESIDUAL budgets (memory already committed by earlier models, bandwidth
fractions consumed, compute time already queued).  This is a
beyond-paper heuristic — per-model DFTSP is optimal for its residual
subproblem, but the joint selection is not proven optimal (the joint
problem adds knapsack coupling across models; see EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import comm, problem
from repro.core.dftsp import (SearchStats, dftsp_schedule,
                              dftsp_schedule_auto, dftsp_schedule_split)
from repro.core.environment import EdgeEnv
from repro.core.quantization import QuantMethod, get_method, swap_seconds
from repro.core.request import Request


@dataclass(frozen=True)
class MultiLLMEnv:
    """Shared edge node hosting several (model, quant) deployments."""
    envs: Dict[str, EdgeEnv]          # model_id -> single-model view
    C: float                          # shared compute (FLOP/s)
    M: float                          # shared memory (bytes)

    @classmethod
    def host(cls, envs: Dict[str, EdgeEnv]) -> "MultiLLMEnv":
        any_env = next(iter(envs.values()))
        epochs = {e.T_E for e in envs.values()}
        if len(epochs) > 1:        # one epoch grid drives the whole node
            raise ValueError(f"hosted models disagree on T_E: {epochs}")
        return cls(envs={k: v.with_(C=any_env.C, M=any_env.M)
                         for k, v in envs.items()},
                   C=any_env.C, M=any_env.M)

    @property
    def T_E(self) -> float:
        """Epoch duration shared by every hosted deployment."""
        return next(iter(self.envs.values())).T_E

    def env_for(self, r: Request) -> EdgeEnv | None:
        """Single-model view serving this request (None if untargeted)."""
        return self.envs.get(r.model_id)

    def weight_bytes(self) -> float:
        """Resident weights of every hosted model (always in memory)."""
        return sum(e.quant.alpha_w * e.cost_model().weight_bytes()
                   for e in self.envs.values())


def tag(requests: Sequence[Request], model_id: str) -> List[Request]:
    """Set ``Request.model_id`` on each request (thin compat wrapper)."""
    for r in requests:
        r.model_id = model_id
    return list(requests)


def random_tagger(model_ids: Sequence[str], seed: int = 0):
    """A ``tag_arrivals`` hook assigning each arrival a pseudo-random
    hosted model — the multi-LLM traffic shape of the conservation suite
    and the multi-engine benchmarks.

    The assignment is a pure function of ``(seed, rid)``, NOT a shared
    RNG stream: the epoch runtime tags arrivals per epoch while the
    continuous runtime tags the same stream per segment window, so any
    stateful tagger would hand the two protocols different model splits
    for identical traffic.  Stateless hashing keeps them like-for-like.
    """
    ids = list(model_ids)

    def tag_arrivals(arrivals: Sequence[Request]) -> List[Request]:
        for r in arrivals:
            rng = np.random.default_rng((seed, r.rid))
            r.model_id = ids[int(rng.integers(len(ids)))]
        return list(arrivals)

    return tag_arrivals


def model_order(menv: MultiLLMEnv, order: str = "weight") -> List[str]:
    """Model visit order for the sequential compute slot.

    * ``weight`` — cheapest resident weights first (default: its requests
      lose the least slack to queueing behind other models' compute);
    * ``name``   — deterministic lexicographic order;
    * ``load``   — cheapest per-request decode cost first.
    """
    envs = menv.envs
    if order == "weight":
        return sorted(envs, key=lambda m: envs[m].cost_model().weight_bytes())
    if order == "name":
        return sorted(envs)
    if order == "load":
        return sorted(envs, key=lambda m: envs[m].cost_model()
                      .decode_flops(envs[m].s_max, [envs[m].s_max]))
    raise ValueError(f"unknown model order {order!r} "
                     "(expected weight|name|load)")


def _kv_bytes(env: EdgeEnv, batch: Sequence[Request],
              quant: Optional[QuantMethod] = None) -> float:
    cm = env.cost_model()
    q = quant or env.quant
    return q.alpha_a * (
        cm.kv_bytes_prefill(env.s_max, len(batch))
        + cm.kv_bytes_decode([r.n for r in batch], env.s_max))


def multi_dftsp_assign(menv: MultiLLMEnv, requests: Sequence[Request],
                       order: str = "weight", quant: str = "env"
                       ) -> Tuple[Dict[str, List[Request]],
                                  Dict[str, QuantMethod], SearchStats]:
    """Joint schedule across hosted models on shared budgets, returning
    the per-model quantization assignment alongside the batches.

    ``quant`` is ``"env"`` (each model's deployed method — the historical
    behavior), ``"auto"`` (per-model throughput-optimal method via
    ``dftsp_schedule_auto``), or a METHODS name pinning every model.
    """
    batches, quants, _, stats = multi_dftsp_assign_split(
        menv, requests, order=order, quant=quant, split=False)
    return batches, quants, stats


def multi_dftsp_assign_split(menv: MultiLLMEnv,
                             requests: Sequence[Request],
                             order: str = "weight", quant: str = "env",
                             split: bool = True,
                             swap_record: Optional[dict] = None
                             ) -> Tuple[Dict[str, List[Request]],
                                        Dict[str, QuantMethod],
                                        Dict[str, List[Tuple[List[Request],
                                                             QuantMethod]]],
                                        SearchStats]:
    """``multi_dftsp_assign`` with the split-epoch extension: each hosted
    model's residual-budget DFTSP may split its queue into two
    differently-quantized sub-batches (``dftsp_schedule_split``), with the
    measured weight-swap latency charged in that model's slot of the
    sequential compute queue.  Returns ``(batches, quants, splits, stats)``
    — ``splits[mid]`` present only when that model actually split;
    ``quants[mid]`` is then the primary sub-batch's method.
    """
    stats = SearchStats()
    by_model: Dict[str, List[Request]] = {m: [] for m in menv.envs}
    for r in requests:
        if r.model_id in by_model:
            by_model[r.model_id].append(r)

    visit = model_order(menv, order)

    quants: Dict[str, QuantMethod] = {m: e.quant for m, e in menv.envs.items()}
    splits: Dict[str, List[Tuple[List[Request], QuantMethod]]] = {}
    mem_left = menv.M - menv.weight_bytes()
    if mem_left < 0:
        return {m: [] for m in menv.envs}, quants, splits, stats
    rho_u_left = rho_d_left = 1.0
    t_queued = 0.0
    out: Dict[str, List[Request]] = {}

    for mid in visit:
        env = menv.envs[mid]
        pool = by_model[mid]
        # residual-budget view: memory = own weights + the shared
        # remainder (dftsp's (1c) re-subtracts the own-weight term), and
        # earlier models' batch compute delays this batch exactly like a
        # longer uplink slot (single compute queue, Fig. 2)
        W = env.cost_model().weight_bytes()
        own_w = env.quant.alpha_w * W
        res_env = env.with_(M=own_w + max(mem_left, 0.0),
                            T_U=env.T_U + t_queued)
        subs: List[Tuple[List[Request], QuantMethod]] = []
        if split and quant == "auto":
            subs, st = dftsp_schedule_split(res_env, pool,
                                            swap_record=swap_record)
            sel = [r for b, _ in subs for r in b]
            q_m = subs[0][1] if subs else env.quant
        elif quant == "auto":
            sel, q_m, st = dftsp_schedule_auto(res_env, pool)
        else:
            q_m = env.quant if quant == "env" else get_method(quant)
            sel, st = dftsp_schedule(res_env, pool, quant=q_m)
        quants[mid] = q_m
        stats.nodes_visited += st.nodes_visited
        stats.leaves_checked += st.leaves_checked

        # enforce the SHARED bandwidth budget (dftsp saw a full link)
        kept: List[Request] = []
        for r in sorted(sel, key=lambda r: comm.rho_min_up(env, r)):
            ru, rd = comm.rho_min_up(env, r), comm.rho_min_down(env, r)
            if ru <= rho_u_left and rd <= rho_d_left:
                kept.append(r)
                rho_u_left -= ru
                rho_d_left -= rd

        def _kept_subs() -> List[Tuple[List[Request], QuantMethod]]:
            ids = {r.rid for r in kept}
            return [([r for r in b if r.rid in ids], q)
                    for b, q in subs if any(r.rid in ids for r in b)]

        if subs:
            while kept and not problem.split_feasible(
                    res_env, _kept_subs(), swap_record=swap_record):
                kept.pop()   # shed costliest-uplink member until feasible
            subs = [(b, q) for b, q in _kept_subs() if b]
            kept = [r for b, _ in subs for r in b]
            quants[mid] = q_m = subs[0][1] if subs else env.quant
        else:
            while kept and not problem.latency_feasible(res_env, kept,
                                                        quant=q_m):
                kept.pop()   # shed costliest-uplink member until feasible
        out[mid] = kept
        if len(subs) > 1:
            splits[mid] = subs
        if kept and subs:
            # sequential sub-batches: KV peaks at the largest sub-batch,
            # weight residency at the heaviest sub-method; epoch time adds
            # every sub-batch's compute plus the inter-sub swap latency
            mem_left -= (max(_kv_bytes(env, b, q) for b, q in subs)
                         + (max(q.alpha_w for _, q in subs)
                            - env.quant.alpha_w) * W)
            prev = None
            for b, q in subs:
                if prev is not None:
                    t_queued += swap_seconds(swap_record, prev, q)
                t_queued += problem.batch_compute_time(env, b, quant=q)
                prev = q
        elif kept:
            # KV under the decided method, plus the weight delta if the
            # decision re-quantized this model's residency
            mem_left -= (_kv_bytes(env, kept, q_m)
                         + (q_m.alpha_w - env.quant.alpha_w) * W)
            t_queued += problem.batch_compute_time(env, kept, quant=q_m)
        else:
            quants[mid] = env.quant     # nothing served: keep the default
    stats.z_solved = sum(len(v) for v in out.values())
    return out, quants, splits, stats


def multi_dftsp(menv: MultiLLMEnv, requests: Sequence[Request],
                order: str = "weight"
                ) -> Tuple[Dict[str, List[Request]], SearchStats]:
    """Joint schedule across hosted models on shared budgets (fixed
    deployed methods; see ``multi_dftsp_assign`` for method selection)."""
    batches, _, stats = multi_dftsp_assign(menv, requests, order=order)
    return batches, stats


def multi_feasible(menv: MultiLLMEnv, batches: Dict[str, List[Request]],
                   order: str = "weight",
                   quants: Optional[Dict[str, QuantMethod]] = None,
                   splits: Optional[Dict[str, List[Tuple[List[Request],
                                                         QuantMethod]]]]
                   = None,
                   swap_record: Optional[dict] = None) -> bool:
    """Authoritative feasibility oracle for a joint multi-model schedule:
    shared OFDMA spectrum, shared memory pool, and per-request deadlines
    under the sequential single-compute-slot execution in ``order``.
    ``quants`` evaluates each model's constraints (weight residency, KV
    factors, compute scale, accuracy) under its decided method.

    ``splits`` (the split-epoch extension) overrides a model's single
    method with its ordered ``(sub_batch, method)`` list: accuracy is
    checked per sub-batch at its OWN method, memory at the peak across
    the sequential sub-batches (largest KV footprint, heaviest weight
    residency), and latency serially — a request in sub-batch j waits
    through every earlier model's compute, its own model's earlier
    sub-batches, and the inter-sub weight swaps (``swap_record``).
    """
    quants = quants or {}
    splits = splits or {}

    def q_for(mid: str) -> QuantMethod:
        return quants.get(mid) or menv.envs[mid].quant

    def subs_for(mid: str, batch: List[Request]
                 ) -> List[Tuple[List[Request], QuantMethod]]:
        subs = splits.get(mid)
        return subs if subs else [(batch, q_for(mid))]

    rho_u = rho_d = 0.0
    mem = 0.0
    for m, e in menv.envs.items():
        alphas = [q.alpha_w for _, q in splits.get(m, [])] \
            or [q_for(m).alpha_w]
        mem += max(alphas) * e.cost_model().weight_bytes()
    for mid, batch in batches.items():
        env = menv.envs.get(mid)
        if env is None:
            if batch:              # non-empty batch for an unhosted model
                return False
            continue
        subs = subs_for(mid, batch)
        if sorted(r.rid for b, _ in subs for r in b) != \
                sorted(r.rid for r in batch):
            return False           # splits must partition the flat batch
        for sub, q in subs:
            for r in sub:
                if r.model_id != mid:
                    return False
                if not problem.accuracy_feasible(env, r, q):
                    return False
                rho_u += comm.rho_min_up(env, r)
                rho_d += comm.rho_min_down(env, r)
        if batch:
            mem += max(_kv_bytes(env, sub, q) for sub, q in subs)
    if rho_u > 1.0 + 1e-9 or rho_d > 1.0 + 1e-9:
        return False
    if mem > menv.M + 1e-6:
        return False
    t_queued = 0.0
    for mid in model_order(menv, order):
        batch = batches.get(mid, [])
        if not batch:
            continue
        env = menv.envs[mid]
        prev: Optional[QuantMethod] = None
        for sub, q in subs_for(mid, batch):
            if prev is not None:
                t_queued += swap_seconds(swap_record, prev, q)
            t = problem.batch_compute_time(env, sub, quant=q)
            for r in sub:
                if r.t_w + env.T_U + t_queued + t + env.T_D > r.tau + 1e-9:
                    return False
            t_queued += t
            prev = q
    return True
