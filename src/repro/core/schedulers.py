"""Batch schedulers: DFTSP (paper Algorithm 1) + the paper's §IV baselines.

* ``dftsp``         — optimal tree search (core/dftsp.py), the contribution;
* ``brute_force``   — same search without pruning/ordering (Table III bench);
* ``static_batching`` (StB) — fixed batch size derived offline from the epoch
  duration and LLM parameters so the *worst-case* batch never overflows
  memory or the epoch deadline; requests admitted FIFO up to that size;
* ``no_batching``   (NoB) — each accelerator unit serves one request at a
  time (n_units concurrent singles per epoch);
* ``greedy``        — slack-ordered greedy admission (a beyond-paper baseline
  that is the natural "good heuristic" anchor for DFTSP's optimality).

Every scheduler has the same signature:
    schedule(env, requests) -> (selected: List[Request], stats: SearchStats)
and must return a batch that satisfies P1 (the simulator re-checks).
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core import comm, problem
from repro.core.dftsp import SearchStats, dftsp_schedule
from repro.core.environment import EdgeEnv
from repro.core.request import Request

Scheduler = Callable[[EdgeEnv, Sequence[Request]],
                     Tuple[List[Request], SearchStats]]


def dftsp(env: EdgeEnv, requests: Sequence[Request]):
    return dftsp_schedule(env, requests)


def brute_force(env: EdgeEnv, requests: Sequence[Request]):
    """Tree search without pruning / child ordering / z upper-bounding —
    the Table III benchmark.  Same (optimal) answer, many more nodes."""
    return dftsp_schedule(env, requests, prune=False, order_desc=False,
                          fast_z_bound=False)


def exhaustive(env: EdgeEnv, requests: Sequence[Request],
               max_n: int = 18):
    """Literal subset enumeration (oracle for optimality tests only)."""
    pool = problem.filter_accuracy(env, requests)
    if len(pool) > max_n:
        raise ValueError(f"exhaustive() is capped at {max_n} requests")
    stats = SearchStats()
    best: List[Request] = []
    for z in range(len(pool), 0, -1):
        if z <= len(best):
            break
        for cand in itertools.combinations(pool, z):
            stats.nodes_visited += 1
            if problem.feasible(env, list(cand), check_accuracy=False):
                best = list(cand)
                break
        if best:
            break
    stats.z_solved = len(best)
    return best, stats


def _static_batch_key(env: EdgeEnv) -> tuple:
    """Cache key over exactly the EdgeEnv fields the derivation reads.
    (EdgeEnv itself is unhashable: QuantMethod carries a dPPL dict.)"""
    q = env.quant
    return (env.model, q.name, q.weight_bits, q.act_bits, q.beta,
            env.C, env.M, env.T_E, env.T_U, env.T_D, env.s_max,
            env.paper_faithful)


_STATIC_BATCH_CACHE: Dict[tuple, int] = {}


def static_batch_size(env: EdgeEnv) -> int:
    """StB's offline batch size: largest B such that a batch of B
    *worst-case* requests (max output level, median channel) is feasible on
    memory and the epoch compute budget (paper §IV: 'set batch size based on
    epoch duration and LLM parameters to avoid GPU overflow').

    Memoized: the result is a pure function of the frozen EdgeEnv, so the
    O(B_max) re-derivation runs once per environment, not once per epoch.
    """
    key = _static_batch_key(env)
    cached = _STATIC_BATCH_CACHE.get(key)
    if cached is not None:
        return cached
    cm = env.cost_model()
    q = env.quant
    n_max = env.s_max                      # worst-case output level
    B = 0
    while True:
        b = B + 1
        mem = (q.alpha_w * cm.weight_bytes()
               + q.alpha_a * (cm.kv_bytes_prefill(env.s_max, b)
                              + cm.kv_bytes_decode([n_max] * b, env.s_max)))
        t = q.beta * (cm.prefill_flops(env.s_max, b)
                      + cm.decode_flops(env.s_max, [n_max] * b)) / env.C
        if mem > env.M or env.T_U + t + env.T_D > env.T_E:
            break
        B = b
        if B >= 4096:                      # safety rail
            break
    _STATIC_BATCH_CACHE[key] = B
    return B


def static_batching(env: EdgeEnv, requests: Sequence[Request]):
    """StB: FIFO admission up to the precomputed size; per-request comm and
    deadline checks still apply (infeasible requests are passed over)."""
    stats = SearchStats()
    B = static_batch_size(env)
    pool = problem.filter_accuracy(env, requests)
    pool = sorted(pool, key=lambda r: r.arrival)
    sel: List[Request] = []
    rho_u = rho_d = 0.0
    for r in pool:
        if len(sel) == B:
            break
        stats.nodes_visited += 1
        ru, rd = comm.rho_min_up(env, r), comm.rho_min_down(env, r)
        if rho_u + ru > 1.0 or rho_d + rd > 1.0:
            continue
        cand = sel + [r]
        if not problem.latency_feasible(env, cand):
            continue
        if not problem.memory_feasible(env, cand):
            break
        sel, rho_u, rho_d = cand, rho_u + ru, rho_d + rd
    stats.z_solved = len(sel)
    return sel, stats


def no_batching(env: EdgeEnv, requests: Sequence[Request]):
    """NoB: n_units accelerators, one request each, no batching.  Each unit
    has 1/n_units of the aggregate compute and memory.  A lone request runs
    at its true prompt length (padding to s' exists only for batching)."""
    stats = SearchStats()
    C_unit, M_unit = env.C / env.n_units, env.M / env.n_units
    cm = env.cost_model()
    q = env.quant
    pool = problem.filter_accuracy(env, requests)
    pool = sorted(pool, key=lambda r: r.arrival)
    sel: List[Request] = []
    rho_u = rho_d = 0.0
    for r in pool:
        if len(sel) == env.n_units:
            break
        stats.nodes_visited += 1
        ru, rd = comm.rho_min_up(env, r), comm.rho_min_down(env, r)
        if rho_u + ru > 1.0 or rho_d + rd > 1.0:
            continue
        mem = (q.alpha_w * cm.weight_bytes()
               + q.alpha_a * (cm.kv_bytes_prefill(r.s, 1)
                              + cm.kv_bytes_decode([r.n], r.s)))
        if mem > M_unit:
            continue
        t = q.beta * (cm.prefill_flops(r.s, 1)
                      + cm.decode_flops(r.s, [r.n])) / C_unit
        if r.t_w + env.T_U + t + env.T_D > r.tau + 1e-12:
            continue
        sel, rho_u, rho_d = sel + [r], rho_u + ru, rho_d + rd
    stats.z_solved = len(sel)
    return sel, stats


def greedy(env: EdgeEnv, requests: Sequence[Request]):
    """Slack-then-cost greedy admission (beyond-paper heuristic anchor)."""
    stats = SearchStats()
    pool = problem.filter_accuracy(env, requests)
    pool = sorted(pool, key=lambda r: (r.n, -(r.tau - r.t_w)))
    sel: List[Request] = []
    for r in pool:
        stats.nodes_visited += 1
        cand = sel + [r]
        if problem.feasible(env, cand, check_accuracy=False):
            sel = cand
    stats.z_solved = len(sel)
    return sel, stats


def nob_feasible(env: EdgeEnv, sel: Sequence[Request]) -> bool:
    """Validity oracle for a NoB assignment (per-unit, true prompt length)."""
    if len(sel) > env.n_units:
        return False
    if not (comm.uplink_feasible(env, sel)
            and comm.downlink_feasible(env, sel)):
        return False
    C_unit, M_unit = env.C / env.n_units, env.M / env.n_units
    cm = env.cost_model()
    q = env.quant
    for r in sel:
        mem = (q.alpha_w * cm.weight_bytes()
               + q.alpha_a * (cm.kv_bytes_prefill(r.s, 1)
                              + cm.kv_bytes_decode([r.n], r.s)))
        t = q.beta * (cm.prefill_flops(r.s, 1)
                      + cm.decode_flops(r.s, [r.n])) / C_unit
        if mem > M_unit or r.t_w + env.T_U + t + env.T_D > r.tau + 1e-12:
            return False
    return True


SCHEDULERS: Dict[str, Scheduler] = {
    "dftsp": dftsp,
    "brute_force": brute_force,
    "stb": static_batching,
    "nob": no_batching,
    "greedy": greedy,
}


def get_scheduler(name: str) -> Scheduler:
    return SCHEDULERS[name]
