"""Quantization model (paper §II-B.3).

Each PTQ method is characterized by:
  alpha_w / alpha_a — memory scale factors for weights / activations+KV,
  beta            — computational-time scale,
  dppl[model]     — perplexity differential (paper Table II + [10]).

``f_accuracy`` maps dPPL to a service-accuracy score in [0,1]
(monotonically decreasing, as the paper requires); a request is
accuracy-feasible iff a_i <= f(dPPL).

The paper treats alpha as a single factor on (m1 + m2); we keep separate
weight/activation factors (W8A16 does NOT shrink the KV cache) and provide
``alpha`` as the paper-faithful aggregate used by the reproduction benches.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence


@dataclass(frozen=True)
class QuantMethod:
    name: str
    weight_bits: int
    act_bits: int
    beta: float                      # compute-time scale vs FP16
    dppl: Dict[str, float] = field(default_factory=dict)
    dppl_default: float = 0.1
    # measured-coefficient overrides (quant/calibration.py): when set, the
    # analytic bits/16 ratios are replaced by values measured on the real
    # quantized trees / engine, so every P2Coefficients and quant=auto
    # descent runs on the engine that will actually serve the decision.
    # ``beta`` itself is a plain field — measured betas arrive via
    # ``dataclasses.replace`` (see calibration.measured_methods).
    alpha_w_measured: Optional[float] = None
    alpha_a_measured: Optional[float] = None

    @property
    def alpha_w(self) -> float:
        if self.alpha_w_measured is not None:
            return self.alpha_w_measured
        return self.weight_bits / 16.0

    @property
    def alpha_a(self) -> float:
        if self.alpha_a_measured is not None:
            return self.alpha_a_measured
        return self.act_bits / 16.0

    @property
    def serve_bits(self):
        """The engine-facing precision spec: plain weight bits for
        weight-only methods, a ``(weight_bits, act_bits)`` pair when the
        method also quantizes activations (W8A8 -> the int8-accumulation
        kernel tier; see ServingEngine._canon_bits)."""
        if self.act_bits < 16 and self.weight_bits < 16:
            return (self.weight_bits, self.act_bits)
        return self.weight_bits

    @property
    def alpha(self) -> float:
        """Paper-faithful single memory factor (dominated by weights)."""
        return self.alpha_w

    def delta_ppl(self, model: str) -> float:
        return self.dppl.get(model, self.dppl_default)


def f_accuracy(dppl: float) -> float:
    """Monotonically decreasing accuracy score of the PPL differential."""
    return math.exp(-dppl)


# Paper Table II + [10] calibration.  W8A16 is the paper's default.
_TABLE2_GPTQ = {"bloom-3b": 0.75, "bloom-7b1": 0.54, "opt-13b": 0.2}
_TABLE2_ZQL = {"bloom-3b": 0.92, "bloom-7b1": 0.59, "opt-13b": 0.42}

METHODS: Dict[str, QuantMethod] = {
    "W16A16": QuantMethod("W16A16", 16, 16, beta=1.0, dppl_default=0.0),
    "W8A16": QuantMethod("W8A16", 8, 16, beta=0.85, dppl_default=0.05,
                         dppl={"bloom-3b": 0.05, "bloom-7b1": 0.04,
                               "opt-13b": 0.03}),
    "W8A8": QuantMethod("W8A8", 8, 8, beta=0.7, dppl_default=0.15),
    "W4A16-GPTQ": QuantMethod("W4A16-GPTQ", 4, 16, beta=0.8,
                              dppl=_TABLE2_GPTQ, dppl_default=0.6),
    "W4A16-ZQL": QuantMethod("W4A16-ZQL", 4, 16, beta=0.75,
                             dppl=_TABLE2_ZQL, dppl_default=0.7),
}


def get_method(name: str) -> QuantMethod:
    return METHODS[name]


def swap_seconds(record: Optional[Dict], m_from: Optional[QuantMethod],
                 m_to: Optional[QuantMethod]) -> float:
    """Weight-swap latency (seconds) charged when an epoch re-serves from
    ``m_from``'s to ``m_to``'s weight residency, looked up in a
    ``quant/calibration.measure_swap_cost`` record.

    Methods sharing a canonical serving precision (the record's
    ``methods`` map — e.g. W8A16 and W8A8 both canonicalize to int8
    weights on interpret backends) swap for free; unmeasured transitions
    fall back to the record's ``default_s`` (the worst measured pair).
    ``record=None`` charges nothing — the Table-II reproduction has no
    swap model, so un-calibrated schedulers keep the historical pricing.
    """
    if record is None or m_from is None or m_to is None:
        return 0.0
    names = record.get("methods", {})
    a = names.get(getattr(m_from, "name", m_from))
    b = names.get(getattr(m_to, "name", m_to))
    if a is None or b is None:
        return float(record.get("default_s", 0.0))
    if a == b:
        return 0.0
    pair = record.get("pairs", {}).get(f"{a}->{b}")
    if pair is None:
        return float(record.get("default_s", 0.0))
    return float(pair["swap_s"])


# ---------------------------------------------------------------------------
# Method selection (quantization as a scheduling decision)
# ---------------------------------------------------------------------------


def dominates(a: QuantMethod, b: QuantMethod, model: str) -> bool:
    """``a`` dominates ``b`` iff it is no worse on every P1-relevant axis
    (alpha_w, alpha_a, beta, dPPL) and strictly better on at least one.
    Any batch feasible under ``b`` is then feasible under ``a`` (smaller
    memory factors, faster compute, superset accuracy pool)."""
    keys_a = (a.alpha_w, a.alpha_a, a.beta, a.delta_ppl(model))
    keys_b = (b.alpha_w, b.alpha_a, b.beta, b.delta_ppl(model))
    return all(x <= y for x, y in zip(keys_a, keys_b)) and keys_a != keys_b


def pareto_methods(methods: Iterable[QuantMethod],
                   model: str) -> List[QuantMethod]:
    """Drop Pareto-dominated methods (dominated methods can never yield a
    larger feasible batch, so pruning them preserves optimality)."""
    pool = list(methods)
    return [m for m in pool
            if not any(dominates(o, m, model) for o in pool if o is not m)]


def candidate_methods(model: str,
                      accuracies: Optional[Sequence[float]] = None,
                      methods: Optional[Iterable[QuantMethod]] = None
                      ) -> List[QuantMethod]:
    """Candidate set for per-epoch method selection over ``model``:
    prefilter by the batch's accuracy requirements (keep a method only if
    it can serve at least one requested ``a_i <= f(dPPL)``), then drop
    Pareto-dominated methods.  Deterministic order: fastest first
    (beta, then dPPL, alpha_w, name) so a first feasible hit at a given
    batch size is also the preferred method."""
    pool = list(methods) if methods is not None else list(METHODS.values())
    if accuracies is not None:
        pool = [m for m in pool
                if any(a <= f_accuracy(m.delta_ppl(model)) + 1e-12
                       for a in accuracies)]
    pool = pareto_methods(pool, model)
    return sorted(pool, key=lambda m: (m.beta, m.delta_ppl(model),
                                       m.alpha_w, m.name))
