"""Edge-node environment: hardware + wireless + epoch protocol constants."""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.config import ModelConfig, V5E, get_arch
from repro.core.costmodel import CostModel
from repro.core.quantization import QuantMethod, get_method


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) / 1000.0


@dataclass(frozen=True)
class EdgeEnv:
    """Everything the scheduler needs to evaluate P1's constraints."""
    model: ModelConfig
    quant: QuantMethod
    # compute/memory (aggregate over the edge server's accelerators)
    C: float                    # FLOP/s
    M: float                    # bytes
    n_units: int                # independent accelerators (NoB baseline)
    # wireless
    B_U: float = 20e6           # uplink bandwidth (Hz)
    B_D: float = 20e6
    p_u: float = dbm_to_watt(20.0)    # user->EN transmit power (W)
    p_d: float = dbm_to_watt(43.0)    # EN->user
    N0: float = dbm_to_watt(-174.0)   # noise PSD (W/Hz)
    # epoch protocol
    T_E: float = 2.0
    T_U: float = 0.25
    T_D: float = 0.25
    s_max: int = 512            # s': prompts padded to this for batching
    paper_faithful: bool = False

    @property
    def T_C(self) -> float:
        """Compute slot: T_C overlaps the adjacent comm slots (Fig. 2)."""
        return self.T_E

    def cost_model(self) -> CostModel:
        return CostModel(self.model, paper_faithful=self.paper_faithful)

    def with_(self, **kw) -> "EdgeEnv":
        return replace(self, **kw)


def paper_env(model: str = "bloom-3b", quant: str = "W8A16",
              **kw) -> EdgeEnv:
    """The paper's §IV testbed: 20x Jetson TX2 (1.33 TFLOPs, 32 GB each)."""
    defaults = dict(
        model=get_arch(model), quant=get_method(quant),
        C=20 * 1.33e12, M=20 * 32e9, n_units=20, paper_faithful=True)
    defaults.update(kw)
    return EdgeEnv(**defaults)


def tpu_env(model: str, quant: str = "W8A16", chips: int = 16,
            **kw) -> EdgeEnv:
    """TPU v5e edge pod-slice (hardware adaptation, DESIGN.md §3)."""
    defaults = dict(
        model=get_arch(model), quant=get_method(quant),
        C=chips * V5E.peak_flops, M=chips * V5E.hbm_bytes, n_units=chips,
        paper_faithful=False)
    defaults.update(kw)
    return EdgeEnv(**defaults)
