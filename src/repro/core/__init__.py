"""The paper's contribution: edge-inference system model, DFTSP scheduler,
quantization trade-off, and the epoch-based serving simulation."""
from repro.core.request import Request, RequestGenerator     # noqa: F401
from repro.core.environment import EdgeEnv, paper_env        # noqa: F401
from repro.core.dftsp import dftsp_schedule                  # noqa: F401
