"""DFTSP — optimal Depth-First Tree-Search with online tree-Pruning
(paper Algorithm 1, §III).

Outer loops (Algorithm 1 lines 2-9):
  * z = |I~| .. 1 (target batch size, decreasing => first hit is optimal);
  * requests sorted by slack tau~ descending; d = z .. |I~| sweeps the
    candidate pool F_d (the top-d slackiest requests).

Tree (for fixed z, d): candidates in F_d are grouped by output-length level
N_1 < N_2 < ... < N_K; a depth-k node chooses v_k = |S'_k| (how many level-k
requests are selected, cheapest-uplink first within the level).  DFS visits
children largest-count first (favoring short-output requests, paper
§III-C(1)) and depth-first so leaves are reached quickly.

Online pruning (paper §III-C(2)):
  * capacity prune — if the remaining levels cannot supply the missing
    z - sum(v) requests, skip the subtree and the lower-index siblings;
  * constraint prune — every P2 constraint is monotone in batch growth
    (uplink/downlink/memory LHS only increase, latency slack only
    decreases), so a partial selection that already violates one can never
    be completed: the branch is redundant and is cut.

Both louvers off (``prune=False``) + ascending child order reproduces the
brute-force benchmark of Table III.  ``SearchStats`` counts visited nodes
so benchmarks can report the complexity reduction.

Feasibility is monotone in z (any feasible batch stays feasible after
removing a request), so ``fast_z_bound`` computes a cheap per-constraint
upper bound on z and starts the descent there — the returned solution is
identical, only wasted top-of-range sweeps are skipped.  Disable it for
the literal Algorithm 1 node-count accounting.

Optimality note: the d-sweep is REQUIRED for optimality.  At
d = rank of the min-slack member of an optimal S*, the pool F_d contains
S* and every pool member has slack >= min-slack(S*); the cheapest-uplink
within-level greedy then dominates S* on every constraint (same counts per
level => same memory, <= uplink/downlink, >= min slack), so the count
vector of S* yields a feasible leaf.  ``d_sweep=False`` (single search on
the full pool) is a fast heuristic, not the paper algorithm.

Quantization as a decision variable: every entry point takes an explicit
``quant`` (``None`` = the env's deployed method, bit-identical to the
historical behavior), and ``dftsp_schedule_auto`` adds an outer METHOD
dimension to the z-descent — candidate methods are prefiltered by the
queue's accuracy demands, Pareto-dominated methods dropped, and (z,
method) pairs are visited batch-size-first so the first feasible hit is
still the maximum-throughput schedule; ties at equal z resolve to the
fastest (lowest beta) method.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import comm, problem
from repro.core.environment import EdgeEnv
from repro.core.quantization import QuantMethod, candidate_methods
from repro.core.request import Request


@dataclass
class SearchStats:
    nodes_visited: int = 0
    leaves_checked: int = 0
    z_solved: int = 0
    pruned: int = 0


def _group_by_level(pool: Sequence[Request]) -> Tuple[List[int],
                                                      Dict[int, List[Request]]]:
    """Level groups N_1 < ... < N_K, cheapest-uplink first within a level,
    built from ONE sort of the pool (not a rescan per level)."""
    levels: List[int] = []
    groups: Dict[int, List[Request]] = {}
    for r in sorted(pool, key=lambda r: (r.n, r.rho_u)):
        if not levels or r.n != levels[-1]:
            levels.append(r.n)
            groups[r.n] = []
        groups[r.n].append(r)
    return levels, groups


def _annotate(env: EdgeEnv, reqs: Sequence[Request]) -> List[Request]:
    """Attach cached per-request quantities used in the inner loops."""
    cm = env.cost_model()
    for r in reqs:
        r.rho_u = comm.rho_min_up(env, r)        # type: ignore[attr-defined]
        r.rho_d = comm.rho_min_down(env, r)      # type: ignore[attr-defined]
        r.kv_tok = cm.kv_bytes_decode([r.n], env.s_max)   # decode KV bytes
        r.dec_flops = cm.decode_flops(env.s_max, [r.n])
    return list(reqs)


class _Ctx:
    """Precomputed (environment, method) quantities for incremental
    checks.  ``quant=None`` reads the env's deployed method.

    ``extra_s`` / ``rho_u0`` / ``rho_d0`` seat the search behind serial
    epoch time (earlier sub-batch compute + weight swap) and already-spent
    spectrum — the residual view a SECONDARY sub-batch of a split epoch
    is scheduled against.  All-zero is the paper's one-batch search.
    """

    def __init__(self, env: EdgeEnv, quant: Optional[QuantMethod] = None,
                 extra_s: float = 0.0, rho_u0: float = 0.0,
                 rho_d0: float = 0.0):
        self.env = env
        self.quant = quant or env.quant
        cm = env.cost_model()
        q = self.quant
        self.weight_mem = q.alpha_w * cm.weight_bytes()
        self.prefill_mem = q.alpha_a * cm.kv_bytes_prefill(env.s_max, 1)
        self.alpha_a = q.alpha_a
        self.prefill_flops = cm.prefill_flops(env.s_max, 1)
        self.beta = q.beta
        self.extra_s = extra_s
        self.rho_u0 = rho_u0
        self.rho_d0 = rho_d0


def _search(ctx: _Ctx, levels: List[int],
            groups: Dict[int, List[Request]], z: int,
            stats: SearchStats, prune: bool, order_desc: bool
            ) -> Optional[List[Request]]:
    """DFS over count vectors (v_1 .. v_K), see module docstring."""
    env = ctx.env
    K = len(levels)
    suffix_cap = [0] * (K + 1)
    for k in range(K - 1, -1, -1):
        suffix_cap[k] = suffix_cap[k + 1] + len(groups[levels[k]])

    # static per-z terms
    mem_base = ctx.weight_mem + ctx.prefill_mem * z
    if mem_base > env.M:
        return None
    comp_base = ctx.beta * ctx.prefill_flops * z / env.C

    chosen: List[Request] = []

    def partial_violates(rho_u: float, rho_d: float, mem: float,
                         dec: float, slack: float) -> bool:
        if (ctx.rho_u0 + rho_u > 1.0 + 1e-12
                or ctx.rho_d0 + rho_d > 1.0 + 1e-12):
            return True
        if mem_base + mem > env.M + 1e-6:
            return True
        t = (env.T_U + ctx.extra_s + comp_base
             + ctx.beta * dec / env.C + env.T_D)
        return t > slack + 1e-12

    def dfs(k: int, remaining: int, rho_u: float, rho_d: float,
            mem: float, dec: float, slack: float) -> Optional[List[Request]]:
        stats.nodes_visited += 1
        if prune and partial_violates(rho_u, rho_d, mem, dec, slack):
            stats.pruned += 1
            return None
        if remaining == 0:
            stats.leaves_checked += 1
            cand = list(chosen)
            if _check(env, cand, ctx.quant, extra_s=ctx.extra_s,
                      rho_u0=ctx.rho_u0, rho_d0=ctx.rho_d0):
                return cand
            return None
        if k == K:
            return None
        if prune and suffix_cap[k] < remaining:
            stats.pruned += 1
            return None
        g = groups[levels[k]]
        top = min(len(g), remaining)
        counts = range(top, -1, -1) if order_desc else range(0, top + 1)
        for v in counts:
            sel = g[:v]
            chosen.extend(sel)
            hit = dfs(k + 1, remaining - v,
                      rho_u + sum(r.rho_u for r in sel),
                      rho_d + sum(r.rho_d for r in sel),
                      mem + ctx.alpha_a * sum(r.kv_tok for r in sel),
                      dec + sum(r.dec_flops for r in sel),
                      min([slack] + [r.tau - r.t_w for r in sel]))
            del chosen[len(chosen) - v:]
            if hit is not None:
                return hit
        return None

    return dfs(0, z, 0.0, 0.0, 0.0, 0.0, float("inf"))


def _check(env: EdgeEnv, cand: List[Request],
           quant: Optional[QuantMethod] = None, extra_s: float = 0.0,
           rho_u0: float = 0.0, rho_d0: float = 0.0) -> bool:
    """Constraints (2b)-(2e) on a complete leaf (authoritative oracle)."""
    if rho_u0 + sum(r.rho_u for r in cand) > 1.0 + 1e-12:
        return False
    if rho_d0 + sum(r.rho_d for r in cand) > 1.0 + 1e-12:
        return False
    if not problem.memory_feasible(env, cand, quant):
        return False
    return problem.latency_feasible(env, cand, quant=quant, t_extra=extra_s)


def _z_upper_bound(env: EdgeEnv, pool: List[Request],
                   quant: Optional[QuantMethod] = None,
                   extra_s: float = 0.0, rho_u0: float = 0.0,
                   rho_d0: float = 0.0) -> int:
    """Cheap per-constraint bound on the max feasible batch size (sound:
    each constraint is evaluated with its own most-favorable requests)."""
    ctx = _Ctx(env, quant)
    n = len(pool)
    # bandwidth bounds
    z_u = _greedy_bound(sorted(r.rho_u for r in pool), 1.0 - rho_u0)
    z_d = _greedy_bound(sorted(r.rho_d for r in pool), 1.0 - rho_d0)
    # memory: weights + z*(prefill + cheapest decode KV)
    kvs = sorted(r.kv_tok * ctx.alpha_a for r in pool)
    z_m = 0
    used = ctx.weight_mem
    for kv in kvs:
        if used + ctx.prefill_mem + kv > env.M:
            break
        used += ctx.prefill_mem + kv
        z_m += 1
    # latency: z*(prefill) + cheapest decode flops vs best slack
    best_slack = max((r.tau - r.t_w for r in pool), default=0.0) \
        - env.T_U - env.T_D - extra_s
    decs = sorted(r.dec_flops for r in pool)
    z_t, tot = 0, 0.0
    for dflops in decs:
        tot += dflops
        t = ctx.beta * (ctx.prefill_flops * (z_t + 1) + tot) / env.C
        if t > best_slack:
            break
        z_t += 1
    return max(0, min(n, z_u, z_d, z_m, z_t))


def _greedy_bound(sorted_costs: List[float], budget: float) -> int:
    tot, z = 0.0, 0
    for c in sorted_costs:
        tot += c
        if tot > budget + 1e-12:
            break
        z += 1
    return z


def _solve_z(ctx: _Ctx, coeff: problem.P2Coefficients,
             pool: List[Request], z: int, stats: SearchStats,
             prune: bool, order_desc: bool, d_sweep: bool
             ) -> Optional[List[Request]]:
    """Algorithm 1's inner body for one target batch size z (slack-ranked
    d-sweep over candidate pools, then the pruned DFS)."""
    ranked = sorted(pool, key=lambda r: coeff.tau_tilde(r, z),
                    reverse=True)
    d_values = range(z, len(pool) + 1) if d_sweep else [len(pool)]
    for d in d_values:
        F_d = ranked[:d]
        levels, groups = _group_by_level(F_d)
        hit = _search(ctx, levels, groups, z, stats, prune, order_desc)
        if hit is not None:
            return hit
    return None


def dftsp_schedule(env: EdgeEnv, requests: Sequence[Request],
                   prune: bool = True, order_desc: bool = True,
                   d_sweep: bool = True, fast_z_bound: bool = True,
                   stats: Optional[SearchStats] = None,
                   quant: Optional[QuantMethod] = None,
                   extra_s: float = 0.0, rho_u0: float = 0.0,
                   rho_d0: float = 0.0
                   ) -> Tuple[List[Request], SearchStats]:
    """Run Algorithm 1.  Returns (optimal batch S, search stats).

    ``prune=False, order_desc=False, fast_z_bound=False`` is the
    brute-force benchmark of Table III (same solution, more nodes).
    ``quant`` evaluates every constraint under an explicit method instead
    of the env's deployed one.  ``extra_s``/``rho_u0``/``rho_d0`` run the
    search against a residual epoch (time already queued serially ahead
    of this batch, spectrum already committed) — the secondary-sub-batch
    view of ``dftsp_schedule_split``; zeros are the paper's search.
    """
    stats = stats or SearchStats()
    pool = problem.filter_accuracy(env, requests, quant)
    if not pool:
        return [], stats
    pool = _annotate(env, pool)
    ctx = _Ctx(env, quant, extra_s=extra_s, rho_u0=rho_u0, rho_d0=rho_d0)
    coeff = problem.P2Coefficients(env, quant, extra_s=extra_s)

    z_start = _z_upper_bound(env, pool, quant, extra_s=extra_s,
                             rho_u0=rho_u0, rho_d0=rho_d0) \
        if fast_z_bound else len(pool)
    for z in range(z_start, 0, -1):
        hit = _solve_z(ctx, coeff, pool, z, stats, prune, order_desc,
                       d_sweep)
        if hit is not None:
            stats.z_solved = z
            return hit, stats
    return [], stats


def dftsp_schedule_auto(env: EdgeEnv, requests: Sequence[Request],
                        methods: Optional[Sequence[QuantMethod]] = None,
                        prune: bool = True, order_desc: bool = True,
                        d_sweep: bool = True, fast_z_bound: bool = True,
                        stats: Optional[SearchStats] = None
                        ) -> Tuple[List[Request], QuantMethod, SearchStats]:
    """Algorithm 1 with the quantization method as an OUTER decision
    dimension.  Returns (optimal batch S, chosen method, stats).

    Candidate methods are prefiltered by the queue's accuracy demands and
    Pareto-pruned (``quantization.candidate_methods``); the z-descent then
    runs globally across the surviving methods — at each z, methods are
    tried fastest-first, so the first feasible hit maximizes batch size
    (the throughput objective) and breaks ties toward the lowest compute
    time.  With an empty queue (or no admissible method) the env's
    deployed method is returned unchanged.
    """
    stats = stats or SearchStats()
    model = env.model.arch_id
    cands = candidate_methods(model, accuracies=[r.a for r in requests],
                              methods=methods)
    # rho_u/rho_d/kv_tok/dec_flops are quant-independent (alpha/beta scale
    # them inside _Ctx / the oracles), so annotate the queue ONCE and share
    # the cached quantities across every candidate method's pool.
    annotated = _annotate(env, requests)
    entries = []          # (method, ctx, coeff, pool, z upper bound)
    for m in cands:
        pool = problem.filter_accuracy(env, annotated, m)
        if not pool:
            continue
        bound = _z_upper_bound(env, pool, m) if fast_z_bound else len(pool)
        if bound < 1:
            continue
        entries.append((m, _Ctx(env, m), problem.P2Coefficients(env, m),
                        pool, bound))
    if not entries:
        return [], env.quant, stats

    for z in range(max(e[4] for e in entries), 0, -1):
        for m, ctx, coeff, pool, bound in entries:
            if bound < z:
                continue
            hit = _solve_z(ctx, coeff, pool, z, stats, prune, order_desc,
                           d_sweep)
            if hit is not None:
                stats.z_solved = z
                return hit, m, stats
    return [], env.quant, stats


def dftsp_schedule_split(env: EdgeEnv, requests: Sequence[Request],
                         methods: Optional[Sequence[QuantMethod]] = None,
                         swap_record: Optional[dict] = None,
                         prune: bool = True, order_desc: bool = True,
                         d_sweep: bool = True, fast_z_bound: bool = True,
                         stats: Optional[SearchStats] = None,
                         rho_u0: float = 0.0, rho_d0: float = 0.0,
                         extra_s: float = 0.0
                         ) -> Tuple[List[Tuple[List[Request], QuantMethod]],
                                    SearchStats]:
    """Split-epoch extension of ``dftsp_schedule_auto``: one epoch's queue
    may be served as TWO sequential sub-batches at different quantization
    methods, with the measured weight-swap latency between them charged in
    the P2 epoch time (DESIGN.md §1.1).

    Returns ``([(batch, method), ...], stats)`` — one entry for a single-
    method epoch (identical to ``dftsp_schedule_auto``'s answer), two when
    a split strictly serves more requests with the swap cost charged.

    The descent explores split points (primary method x primary batch x
    secondary method) with online pruning:

    * **swap-domination prune** — a (primary, secondary) pair is dominated
      when the swap cost plus the primary's compute eats the residual
      queue's entire slack: the secondary's cheap z-bound at the charged
      serial offset is 0, so no sub-batch can repay the swap.  Skipped
      without searching (``stats.pruned``).
    * **capacity prune** — a pair whose optimistic total (primary size +
      secondary z-bound) cannot beat the incumbent is skipped.

    A split is only adopted when it serves STRICTLY more than the best
    single-method schedule — at equal service the swap only adds epoch
    time — so split throughput >= single-method throughput by
    construction, with swap costs charged (the property
    ``tests/test_quant_splits.py`` pins).
    """
    from repro.core.quantization import swap_seconds
    stats = stats or SearchStats()
    kw = dict(prune=prune, order_desc=order_desc, d_sweep=d_sweep,
              fast_z_bound=fast_z_bound)

    best_sel, best_m, _ = dftsp_schedule_auto(
        env, requests, methods=methods, stats=stats, **kw)
    if not best_sel:
        return [], stats
    best: List[Tuple[List[Request], QuantMethod]] = [(best_sel, best_m)]
    best_total = len(best_sel)

    model = env.model.arch_id
    cands = candidate_methods(model, accuracies=[r.a for r in requests],
                              methods=methods)
    annotated = _annotate(env, requests)
    if len(cands) < 2 or best_total >= len(annotated):
        return best, stats        # nothing left to split toward

    for m_p in cands:
        # primary sub-batch: the best batch this method alone can serve
        if m_p.name == best_m.name:
            sel_p = best_sel
        else:
            sel_p, _ = dftsp_schedule(env, annotated, quant=m_p,
                                      stats=stats, extra_s=extra_s,
                                      rho_u0=rho_u0, rho_d0=rho_d0, **kw)
        if not sel_p:
            continue
        taken = {r.rid for r in sel_p}
        residual = [r for r in annotated if r.rid not in taken]
        if not residual:
            continue
        t_primary = problem.batch_compute_time(env, sel_p, quant=m_p)
        rho_u1 = rho_u0 + sum(r.rho_u for r in sel_p)
        rho_d1 = rho_d0 + sum(r.rho_d for r in sel_p)
        for m_s in cands:
            if m_s.name == m_p.name:
                continue
            pool_s = problem.filter_accuracy(env, residual, m_s)
            if not pool_s:
                continue
            serial = extra_s + t_primary + swap_seconds(swap_record,
                                                        m_p, m_s)
            z2_bound = _z_upper_bound(env, pool_s, m_s, extra_s=serial,
                                      rho_u0=rho_u1, rho_d0=rho_d1)
            if z2_bound < 1:          # swap-domination prune
                stats.pruned += 1
                continue
            if len(sel_p) + min(z2_bound, len(pool_s)) <= best_total:
                stats.pruned += 1     # capacity prune
                continue
            sel_s, _ = dftsp_schedule(env, pool_s, quant=m_s, stats=stats,
                                      extra_s=serial, rho_u0=rho_u1,
                                      rho_d0=rho_d1, **kw)
            if len(sel_p) + len(sel_s) > best_total:
                best = [(sel_p, m_p), (sel_s, m_s)]
                best_total = len(sel_p) + len(sel_s)
    stats.z_solved = best_total
    return best, stats
