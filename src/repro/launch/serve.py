"""Serving launcher: DFTSP-scheduled epoch serving on a real JAX model.

The paper end-to-end: Poisson arrivals -> DFTSP batch selection under the
P1 constraints -> batched prefill + decode on the model.  Reduced configs
run on the host; the full configs are validated by the dry-run.

Usage:
  python -m repro.launch.serve --arch bloom-3b --epochs 5 --rate 10 \
      --quant W8A16 --reduced
"""
from __future__ import annotations

import argparse

from repro.config import get_arch
from repro.core.environment import paper_env, tpu_env
from repro.core.policy import get_policy
from repro.serving.engine import ServingEngine
from repro.serving.runtime import EngineExecutor, EpochRuntime

REDUCED = dict(n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
               d_ff=512, vocab=2048)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bloom-3b")
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--rate", type=float, default=10.0)
    ap.add_argument("--scheduler", default="dftsp",
                    help="policy registry spec, e.g. dftsp, stb, "
                         "dftsp:d_sweep=false")
    ap.add_argument("--quant", default="W8A16",
                    help="env's deployed method; pass "
                         "--scheduler dftsp:quant=auto to let the "
                         "control plane pick the method per epoch")
    ap.add_argument("--bits", type=int, default=8,
                    help="engine's DEFAULT weight bits (0 = fp); "
                         "per-epoch decisions override via the "
                         "multi-precision weight cache")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tpu-env", action="store_true",
                    help="use the v5e cost model instead of the paper's")
    ap.add_argument("--batch-capacity", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--n-max", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    env_fn = tpu_env if args.tpu_env else paper_env
    env = env_fn(args.arch, args.quant)

    if args.reduced:
        red = dict(REDUCED)
        red["n_kv_heads"] = min(cfg.n_kv_heads, red["n_heads"])
        cfg = cfg.scaled(**red)
    engine = ServingEngine(cfg, batch_capacity=args.batch_capacity,
                           s_max=args.s_max, n_max=args.n_max,
                           quant_bits=args.bits)
    runtime = EpochRuntime(env, get_policy(args.scheduler),
                           EngineExecutor(engine))
    trace = runtime.run(rate=args.rate, n_epochs=args.epochs,
                        warmup_epochs=0)
    print(f"[serve] epochs={trace.epochs} served={trace.served} "
          f"tokens={trace.generated_tokens} "
          f"truncated={trace.truncated} "
          f"throughput={trace.throughput:.2f} req/s "
          f"batches={trace.batches} "
          f"methods={trace.served_by_method}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
