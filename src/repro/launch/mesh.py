"""Production meshes (defined as functions so importing this module never
touches jax device state — device count locks on first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever the current host offers (smoke tests / examples)."""
    n = jax.device_count()
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
