"""Sharded step functions: the bridge between models and meshes.

``param_specs`` assigns every parameter leaf a PartitionSpec from
name-based tensor-parallel rules (Megatron layout adapted per family);
``batch_specs`` / ``cache_specs`` shard activations and KV caches.  All
rules are divisibility-aware: a dim that doesn't divide its mesh axes
falls back to replicated (e.g. 56 heads on a 16-way model axis).

Step builders return (fn, in_shardings, out_shardings) ready for
``jax.jit(fn, in_shardings=...).lower(...)`` — used by the real launchers
(train.py / serve.py) and the dry-run alike.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models.api import Model, build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.utils.remat import remat_scan
from repro.utils.sharding import axis_ctx_for_mesh

# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# last-dim sharded on "model" (column parallel)
_COL_KEYS = frozenset({
    "wq", "wk", "wv", "w1", "w3", "w_up", "w_gates", "ffn_w1", "ffn_w3",
    "in_proj", "lm_head", "embed", "wi", "wf",
})
# dim -2 sharded on "model" (row parallel; output stays unsharded pre-psum)
_ROW_KEYS = frozenset({"wo", "w2", "w_down", "ffn_w2", "out_proj"})
# MoE stacked expert weights: expert axis is dim -3 for w1/w3 (E, dm, df)
_MOE_KEYS = frozenset({"w1", "w2", "w3"})


def _leaf_key(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def _path_has(path, name: str) -> bool:
    return any(str(getattr(e, "key", "")) == name for e in path)


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0


def _spec_for(path, leaf, mesh: Mesh, fsdp: bool) -> P:
    key = _leaf_key(path)
    nd = leaf.ndim
    entries = [None] * nd
    if nd >= 2:
        if _path_has(path, "moe") and key in _MOE_KEYS \
                and _divisible(leaf.shape[nd - 3], mesh, "model"):
            # stacked (L, E, dm, df) or unstacked (E, dm, df):
            # expert-parallel over the E axis
            entries[nd - 3] = "model"
        elif key in _COL_KEYS and _divisible(leaf.shape[-1], mesh, "model"):
            entries[-1] = "model"
        elif key in _ROW_KEYS and _divisible(leaf.shape[-2], mesh, "model"):
            entries[-2] = "model"
        elif _path_has(path, "moe") and key in _MOE_KEYS:
            # experts don't divide: fall back to hidden-dim tensor parallel
            if key == "w2" and _divisible(leaf.shape[-2], mesh, "model"):
                entries[-2] = "model"
            elif _divisible(leaf.shape[-1], mesh, "model"):
                entries[-1] = "model"
    if fsdp and nd >= 2:
        # ZeRO-3 style: storage additionally sharded over 'data' on the
        # last still-replicated divisible dim (XLA gathers per layer-slice)
        for i in range(nd - 1, -1, -1):
            if entries[i] is None and leaf.shape[i] > 1 \
                    and _divisible(leaf.shape[i], mesh, "data"):
                entries[i] = "data"
                break
    return P(*entries)


def param_specs(model: Model, mesh: Mesh, fsdp: bool = True) -> Any:
    """PartitionSpec tree for the model's params (via eval_shape; no alloc).

    ``fsdp=True`` (default) additionally shards weight storage over the
    'data' axis — required for the 100B+ archs whose TP=16 shard alone
    (~15 GB) would not leave HBM headroom, and how real v5e deployments
    of that scale store weights.
    """
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, fsdp), shapes)


# ---------------------------------------------------------------------------
# Activation / cache sharding
# ---------------------------------------------------------------------------


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_size(mesh: Mesh) -> int:
    out = 1
    for a in _batch_axes(mesh):
        out *= mesh.shape[a]
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                specs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, P]:
    """Shard every input's batch dim over (pod, data) when divisible."""
    axes = _batch_axes(mesh)
    out = {}
    for k, v in specs.items():
        b = v.shape[0]
        if axes and b % _batch_size(mesh) == 0:
            out[k] = P(axes, *([None] * (v.ndim - 1)))
        else:
            out[k] = P(*([None] * v.ndim))
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shapes: Any,
                batch: int, seq_axis: Optional[str] = "model") -> Any:
    """Shard cache leaves: the batch dim over (pod,data), and the slot /
    sequence dim (>= 1024 slots) over ``seq_axis``.

    The batch dim is identified by its exact size (init_cache(batch, ...)
    builds every leaf with it); the slot dim is the first large divisible
    dim after it.  32k-slot x 128-request caches are the dominant serving
    footprint — slot sharding is what makes decode_32k fit (1a:1 with the
    paper's m2 memory terms, just distributed).
    """
    axes = _batch_axes(mesh)
    bsz = _batch_size(mesh)

    def spec(leaf):
        nd = leaf.ndim
        entries = [None] * nd
        start = 0
        if batch > 1:
            for i, d in enumerate(leaf.shape):
                if d == batch and axes and d % bsz == 0:
                    entries[i] = axes
                    start = i + 1
                    break
        if seq_axis:
            for i in range(start, nd):
                d = leaf.shape[i]
                if (entries[i] is None and d >= 1024
                        and d % mesh.shape[seq_axis] == 0):
                    entries[i] = seq_axis
                    break
        return P(*entries)

    return jax.tree.map(spec, cache_shapes)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def make_train_step_fn(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                       microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` runs gradient accumulation over a lax.scan of
    batch slices: activation peak scales with B/microbatches while the
    optimizer step still sees the full-batch gradient (§Perf lever for
    the activation-bound train shapes).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def step(params, opt_state, batch):
        if microbatches > 1:
            # statically unrolled (a scanned microbatch axis trips GSPMD's
            # gather partitioner when the embedding is FSDP-sharded)
            n = microbatches
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            loss, metrics = 0.0, None
            for i in range(n):
                mb = jax.tree.map(
                    lambda v: v[i * (v.shape[0] // n):
                                (i + 1) * (v.shape[0] // n)], batch)
                (l, metrics), g = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, mb)
                grads = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / n, grads, g)
                loss = loss + l / n
        else:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        return new_params, new_opt, {**metrics, **om}

    return step


def make_prefill_fn(model: Model, cache_len: int):
    def step(params, batch):
        return model.prefill(params, batch, cache_len)
    return step


def make_decode_fn(model: Model, pos: int):
    """One serve_step: decode a single token at position ``pos`` against
    the full cache (the dry-run's decode shapes)."""
    def step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, jnp.int32(pos))
    return step


def build_step(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opt_cfg: Optional[AdamWConfig] = None,
               seq_shard_decode: bool = False,
               microbatches: int = 1):
    """Assemble (fn, example_args, in_shardings, out_shardings) for one
    (arch x shape) pair on ``mesh``.  Everything is ShapeDtypeStructs —
    nothing is allocated.
    """
    model = build_model(arch_cfg)
    pspecs = param_specs(model, mesh)
    p_shapes = jax.eval_shape(model.init,
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    in_specs = model.input_specs(shape)
    bspecs = batch_specs(arch_cfg, shape, mesh, in_specs)

    if shape.kind == "train":
        fn = make_train_step_fn(model, opt_cfg, microbatches=microbatches)
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        opt_specs = type(opt_shapes)(
            step=P(), mu=pspecs, nu=pspecs)
        args = (p_shapes, opt_shapes, in_specs)
        in_sh = (shardings(mesh, pspecs), shardings(mesh, opt_specs),
                 shardings(mesh, bspecs))
        out_sh = None       # propagate from inputs
        return fn, args, in_sh, out_sh, (0, 1)      # donate params + opt

    B = shape.global_batch
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, B, shape.seq_len))
    cspecs = cache_specs(arch_cfg, mesh, cache_shapes, batch=B)

    if shape.kind == "prefill":
        fn = make_prefill_fn(model, cache_len=shape.seq_len)
        args = (p_shapes, in_specs)
        in_sh = (shardings(mesh, pspecs), shardings(mesh, bspecs))
        logit_spec = P(_batch_axes(mesh) or None, None) \
            if B % max(_batch_size(mesh), 1) == 0 else P(None, None)
        out_sh = (NamedSharding(mesh, logit_spec), shardings(mesh, cspecs))
        return fn, args, in_sh, out_sh, ()

    # decode: one token against a seq_len cache
    fn = make_decode_fn(model, pos=shape.seq_len - 1)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = batch_specs(arch_cfg, shape, mesh, {"tokens": tok})["tokens"]
    args = (p_shapes, cache_shapes, tok)
    in_sh = (shardings(mesh, pspecs), shardings(mesh, cspecs),
             NamedSharding(mesh, tok_spec))
    return fn, args, in_sh, None, (1,)              # donate the cache


def lower_step(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               remat: Optional[bool] = None, **kw):
    """Lower one (arch x shape x mesh) combination (dry-run unit)."""
    fn, args, in_sh, out_sh, donate = build_step(arch_cfg, shape, mesh, **kw)
    if remat is None:
        remat = shape.kind == "train"    # layer remat only matters under AD
    with mesh:
        with axis_ctx_for_mesh(mesh, batch=("pod", "data"), model="model"):
            with remat_scan(remat):
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=donate)
                return jitted.lower(*args)
