"""Distributed training launcher.

Runs the sharded train step (launch/steps.py) on whatever mesh the host
offers — the same step function the dry-run lowers for the production
meshes, so a passing dry-run config is exactly what this would execute on
a real pod.

Usage:
  python -m repro.launch.train --arch olmo-1b --steps 100 \
      --batch 32 --seq 256 --reduced        # host-size run
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_arch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (make_train_step_fn, param_specs, shardings)
from repro.models.api import build_model
from repro.train.data import SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.utils.remat import remat_scan
from repro.utils.sharding import axis_ctx_for_mesh

REDUCED = dict(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
               d_ff=512, vocab=2048)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (host-scale smoke)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        red = dict(REDUCED)
        if cfg.is_moe:
            red["d_ff"] = 256
        red["n_kv_heads"] = min(cfg.n_kv_heads, red["n_heads"])
        cfg = cfg.scaled(**red)
    model = build_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    step_fn = make_train_step_fn(model, opt_cfg)

    pspecs = param_specs(model, mesh, fsdp=False)
    data = SyntheticLM(cfg, args.batch, args.seq)

    with mesh:
        with axis_ctx_for_mesh(mesh):
            with remat_scan(True):
                params = jax.jit(
                    model.init,
                    out_shardings=shardings(mesh, pspecs))(jax.random.key(0))
                opt = adamw_init(params)
                step = jax.jit(step_fn, donate_argnums=(0, 1))
                t0 = time.time()
                for i in range(args.steps):
                    batch = {k: jnp.asarray(v)
                             for k, v in data.next_batch().items()}
                    params, opt, metrics = step(params, opt, batch)
                    if i % 10 == 0 or i == args.steps - 1:
                        print(f"step {i:5d} loss={float(metrics['loss']):.4f}"
                              f" lr={float(metrics['lr']):.2e}"
                              f" ({time.time() - t0:.1f}s)")
    if args.checkpoint:
        from repro.train import checkpoint as ck
        ck.save(args.checkpoint, (params, opt))
        print(f"saved {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
