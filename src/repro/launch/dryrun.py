"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for the 2x16x16 production mesh.  For each
combination we record compiled memory analysis (fits/doesn't), FLOPs and
bytes from cost_analysis, and the collective-bytes total parsed from the
HLO text (for the §Roofline terms).

Usage:
  python -m repro.launch.dryrun                       # all 40 pairs x 2 meshes
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --multi-pod-only --json out.json
"""
from __future__ import annotations

import os
# 512 placeholder devices for the production meshes; ICM disabled so the
# CPU backend's bf16->f32 legalization converts are not hoisted out of the
# layer scan (a CPU-only artifact that would triple the apparent KV-cache
# footprint — TPU consumes bf16 natively and never creates them).
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512" + \
    " --xla_disable_hlo_passes=while-loop-invariant-code-motion"

import argparse
import json
import sys
import time
import traceback

import jax

from repro.config import (INPUT_SHAPES, applicable_shapes, get_arch,
                          get_shape, list_archs)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, lower_step
from repro.roofline.analysis import analyze_lowered


def _tree_bytes(tree) -> float:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree)
               if hasattr(l, "size"))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            seq_shard_decode: bool = False, verbose: bool = True,
            kv_bits: int = 16) -> dict:
    cfg = get_arch(arch)
    if kv_bits != 16:
        cfg = cfg.scaled(kv_bits=kv_bits)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # donation fraction (TPU aliases donated args onto outputs; the AOT CPU
    # analysis does not, so we correct the reported footprint)
    _, args, _, _, donate = build_step(cfg, shape, mesh)
    total_b = _tree_bytes(args)
    don_b = sum(_tree_bytes(args[i]) for i in donate)
    t0 = time.time()
    lowered = lower_step(cfg, shape, mesh,
                         seq_shard_decode=seq_shard_decode)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = analyze_lowered(cfg, shape, mesh, lowered, compiled,
                          donated_frac=don_b / total_b if total_b else 0.0)
    rec.update({"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "t_lower_s": round(t_lower, 1),
                "t_compile_s": round(t_compile, 1)})
    if verbose:
        print(f"  mem/device: {rec['bytes_per_device'] / 2**30:.2f} GiB | "
              f"flops: {rec['hlo_flops']:.3e} | "
              f"coll: {rec['collective_bytes']:.3e} B | "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--seq-shard-decode", action="store_true",
                    help="shard long-context decode caches over 'model'")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16],
                    help="int8 KV cache for decode shapes (§Perf pair 3)")
    ap.add_argument("--json", default=None, help="write results to file")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(list_archs(assigned_only=True))
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    results, failures = [], []
    for arch in archs:
        cfg = get_arch(arch)
        shapes = [args.shape] if args.shape else list(applicable_shapes(cfg))
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                print(f"[dryrun] {tag}")
                try:
                    results.append(run_one(arch, shape_name, mp,
                                           args.seq_shard_decode,
                                           kv_bits=args.kv_bits))
                except Exception as e:
                    traceback.print_exc()
                    failures.append({"case": tag, "error": repr(e)})

    print(f"\n[dryrun] {len(results)} ok, {len(failures)} failed")
    for f in failures:
        print(f"  FAIL {f['case']}: {f['error'][:200]}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"results": results, "failures": failures}, fh,
                      indent=1)
        print(f"[dryrun] wrote {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
