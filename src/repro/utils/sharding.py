"""Sharding helpers.

Models are written against *logical* axes (``batch``, ``model``) and only
apply ``with_sharding_constraint`` when a launcher has installed an axis
context.  Smoke tests / single-device runs never install one, so the same
model code runs unconstrained on one CPU device.

Constraints are divisibility-aware: if a tensor dim is not divisible by the
mesh axes mapped to it (e.g. 56 attention heads over a 16-way model axis),
that dim falls back to replicated instead of failing at lowering.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _ctx() -> Optional["AxisCtx"]:
    return getattr(_state, "ctx", None)


class AxisCtx:
    """Maps logical axis names to physical mesh axis names.

    ``batch`` -> tuple of mesh axes the batch dim is sharded over
    (("data",) single-pod, ("pod", "data") multi-pod, or () replicated);
    ``model`` -> the tensor-parallel mesh axis (or None).
    ``sizes`` -> physical mesh axis sizes, used for divisibility checks.
    """

    def __init__(self, batch: Sequence[str] = ("data",),
                 model: Optional[str] = "model",
                 sizes: Optional[Dict[str, int]] = None):
        self.batch: Tuple[str, ...] = tuple(batch)
        self.model = model
        self.sizes = dict(sizes or {})

    def resolve(self, name: Optional[str]):
        if name is None:
            return None
        if name == "batch":
            return self.batch if self.batch else None
        if name == "model":
            return self.model
        raise ValueError(f"unknown logical axis {name!r}")

    def divisor(self, name: Optional[str]) -> int:
        axes = self.resolve(name)
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return math.prod(self.sizes.get(a, 1) for a in axes)


@contextlib.contextmanager
def axis_ctx(batch: Sequence[str] = ("data",), model: Optional[str] = "model",
             sizes: Optional[Dict[str, int]] = None):
    prev = _ctx()
    _state.ctx = AxisCtx(batch, model, sizes)
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def axis_ctx_for_mesh(mesh, batch: Sequence[str] = ("data",),
                      model: Optional[str] = "model"):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch = tuple(a for a in batch if a in sizes)
    model = model if (model in sizes) else None
    return axis_ctx(batch, model, sizes)


def logical_spec(*names: Optional[str],
                 shape: Optional[Tuple[int, ...]] = None) -> Optional[P]:
    """Resolve logical dim names to a PartitionSpec under the active context.

    Returns None when no context is installed (=> no constraint applied).
    When ``shape`` is given, dims not divisible by their mapped mesh axes
    fall back to replicated.
    """
    ctx = _ctx()
    if ctx is None:
        return None
    entries = []
    for i, n in enumerate(names):
        if shape is not None and n is not None:
            if shape[i] % ctx.divisor(n) != 0:
                entries.append(None)
                continue
        entries.append(ctx.resolve(n))
    return P(*entries)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` against logical dim names (no-op without
    an installed axis context; non-divisible dims fall back to replicated)."""
    spec = logical_spec(*names, shape=tuple(x.shape))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def active() -> bool:
    return _ctx() is not None


def axis_divisor(name: str) -> int:
    """Product of mesh-axis sizes behind a logical axis (1 if no context)."""
    ctx = _ctx()
    return 1 if ctx is None else ctx.divisor(name)
