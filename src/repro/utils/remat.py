"""Remat (activation-checkpoint) policy, installed by launchers.

Models wrap their scan-over-layers bodies in :func:`maybe_remat`.  Without
an installed policy this is identity (smoke tests, serving).  Training
launchers install ``remat_scan()`` so each layer's activations (including
the S x S attention intermediates) are recomputed in backward instead of
saved — the difference between ~GBs and ~TBs of temp at 4k x 256 batch.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable

import jax

_state = threading.local()


def remat_enabled() -> bool:
    return getattr(_state, "on", False)


@contextlib.contextmanager
def remat_scan(on: bool = True):
    prev = remat_enabled()
    _state.on = on
    try:
        yield
    finally:
        _state.on = prev


def maybe_remat(body: Callable) -> Callable:
    """Checkpoint a scan body when the policy is active (trace-time check)."""
    if remat_enabled():
        return jax.checkpoint(body)
    return body
