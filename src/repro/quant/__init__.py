from repro.quant.ptq import (QTensor, dequantize, pack_int4, quantize,
                             quantize_tree, tree_bytes, unpack_int4)
from repro.quant.calibration import measure_alpha, measure_dppl

__all__ = ["QTensor", "quantize", "dequantize", "pack_int4", "unpack_int4",
           "quantize_tree", "tree_bytes", "measure_alpha", "measure_dppl"]
