"""Post-training quantization substrate (real PTQ, not the analytic model).

Per-channel symmetric round-to-nearest weight quantization along the
reduction axis (-2), scales per output channel (-1):

  w[..., :, j]  ~=  q[..., :, j] * scale[..., 0, j],
  q int8 (8-bit) or int4 (packed two-rows-per-int8 along -2),
  scale = max|w| / qmax  over axis -2 (keepdims).

Leading axes are PRESERVED — a scan-stacked layer tree (L, K, N) quantizes
to q (L, K, N) + scale (L, 1, N), so ``jax.lax.scan`` over layers slices
``QTensor`` leaves exactly like fp weights (QTensor is a registered pytree
whose children are (q, scale)).

``quantize_tree`` converts every >=2D floating leaf of a model's params
(embeddings included) and leaves small vectors (norm gains, biases)
untouched — matching how real deployments quantize (matmul weights only).

The paper's ``alpha`` (memory scale) is *measured* from these trees via
``tree_bytes`` (see calibration.py) rather than assumed; the paper's values
fall out as the w-bits/16 ratio they predicted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any

INT4_MAX = 7
INT8_MAX = 127


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """Per-channel symmetric quantized weight.

    q: int8 carrier, same shape as the source except axis -2 is halved for
    bits=4 (two nibbles per int8: row 2i -> low, row 2i+1 -> high);
    scale: (..., 1, N) float32.  ``shape``/``dtype`` describe the logical
    dequantized tensor at quantization time; only its last-two dims are
    relied on after pytree slicing (scan strips leading axes).

    ``act_bits`` records the ACTIVATION precision this weight should be
    consumed at (16 = fp activations, 8 = dynamic per-row int8 -> the
    W8A8 int8-accumulation kernel).  It rides in the pytree aux so the
    serving method survives scan slicing and jit boundaries.
    """
    q: jax.Array
    scale: jax.Array
    bits: int
    shape: Tuple[int, ...]
    dtype: Any
    act_bits: int = 16

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.shape, self.dtype,
                                      self.act_bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, *aux)

    @property
    def nbytes(self) -> int:
        return self.q.size * self.q.dtype.itemsize \
            + self.scale.size * self.scale.dtype.itemsize


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 values (int8 storage, [-8,7]) pairwise along axis -2.
    Rows must be even: row 2i -> low nibble, row 2i+1 -> high nibble."""
    lo = q[..., 0::2, :] & 0x0F
    hi = (q[..., 1::2, :] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4: (..., R/2, C) int8 -> (..., R, C) in [-8, 7].

    Index-free even/odd reconstruction: output row r reads packed row
    r//2 (a repeat along -2, no stack+reshape interleave tile), then a
    parity-selected shift sign-extends the right nibble — even rows
    ``(x << 4) >> 4`` (low), odd rows ``x >> 4`` (high), both arithmetic
    on int8.  Bitwise-identical to the historical stack-based unpack.
    """
    rep = jnp.repeat(packed, 2, axis=-2)
    row = jax.lax.broadcasted_iota(jnp.int32, rep.shape, rep.ndim - 2)
    lshift = jnp.where(row % 2 == 0, 4, 0).astype(jnp.int8)
    return ((rep << lshift) >> 4).astype(jnp.int8)


def quantize(w: jax.Array, bits: int = 8, act_bits: int = 16) -> QTensor:
    """Per-output-channel symmetric RTN quantization (reduction axis -2)."""
    assert bits in (4, 8), bits
    assert act_bits in (8, 16), act_bits
    assert w.ndim >= 2, w.shape
    wf = w.astype(jnp.float32)
    qmax = INT4_MAX if bits == 4 else INT8_MAX
    absmax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -qmax - 1, qmax).astype(jnp.int8)
    if bits == 4:
        if q.shape[-2] % 2:
            pad = [(0, 0)] * q.ndim
            pad[-2] = (0, 1)
            q = jnp.pad(q, pad)
        q = pack_int4(q)
    return QTensor(q=q, scale=scale, bits=bits, shape=tuple(w.shape),
                   dtype=w.dtype, act_bits=act_bits)


def quantize_rowwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dynamic per-row symmetric activation quantization (absmax / 127).

    x (..., K) -> (int8 values (..., K), f32 scales (..., 1)).  The
    reduction runs over the full K axis so one scale per row suffices
    for the whole int32 accumulation of an x @ w contraction — the
    rescale can then happen ONCE at writeout (kernels/quant_matmul.py).
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    # multiply by the f32 reciprocal, NOT divide: XLA strength-reduces a
    # constant-divisor division to this multiply under jit but not in
    # eager mode, and the kernel/oracle pair needs bitwise-equal scales
    scale = jnp.where(absmax > 0, absmax * jnp.float32(1.0 / INT8_MAX), 1.0)
    q = jnp.clip(jnp.round(xf / scale),
                 -INT8_MAX - 1, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(t: QTensor) -> jax.Array:
    q = t.q
    if t.bits == 4:
        q = unpack_int4(q)[..., :t.shape[-2], :]
    w = q.astype(jnp.float32) * t.scale
    return w.astype(t.dtype)


def fake_quantize(w: jax.Array, bits: int = 8) -> jax.Array:
    """Quantize-dequantize roundtrip (activation fake-quant / tests)."""
    return dequantize(quantize(w, bits))


def _is_weight(leaf: Any) -> bool:
    return (isinstance(leaf, jax.Array) and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating))


# Param names that are true matmul weights consumed through common.mm() /
# maybe_dequant().  Scan stacking prepends a layer axis to every leaf, so
# shape alone cannot distinguish a stacked norm gain (L, dm) from an
# embedding (V, dm) — names can.
MATMUL_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "w1", "w2", "w3", "router", "lm_head", "embed",
})


def _leaf_key(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def quantize_tree(params: Params, bits: int = 8,
                  keys: frozenset = MATMUL_KEYS,
                  act_bits: int = 16) -> Params:
    """Quantize the named matmul leaves; keep everything else fp.

    ``act_bits=8`` tags every quantized leaf for int8-activation serving
    (the W8A8 kernel path); weights themselves are identical to
    ``act_bits=16`` — the tag only changes how ``common.mm`` consumes
    them."""
    def maybe(path, w):
        if _leaf_key(path) in keys and _is_weight(w):
            return quantize(w, bits, act_bits=act_bits)
        return w
    return jax.tree_util.tree_map_with_path(maybe, params)


def dequantize_tree(params: Params) -> Params:
    return jax.tree.map(
        lambda l: dequantize(l) if isinstance(l, QTensor) else l, params,
        is_leaf=lambda l: isinstance(l, QTensor))


def tree_bytes(params: Params) -> int:
    """Total parameter bytes of a (possibly quantized) tree."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        elif isinstance(leaf, jax.Array):
            total += leaf.size * leaf.dtype.itemsize
    return total
