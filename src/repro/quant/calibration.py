"""Measured quantization effects: alpha (memory) and dPPL (accuracy).

The paper takes alpha/beta/dPPL from offline exhaustive evaluation ([10],
Table II).  Here both are *measured* on the actual JAX models:

  * ``measure_alpha``  — bytes(quantized tree) / bytes(fp tree);
  * ``measure_dppl``   — perplexity difference between the fp and the
    weight-quantized model on a fixed synthetic eval set (real models would
    use WikiText; the machinery is identical).

``calibrate`` packages both into a ``QuantMethod``-compatible record so the
scheduler can run on measured numbers instead of the paper's table — the
table remains the default so the reproduction is exact.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.api import build_model
from repro.quant.ptq import dequantize_tree, quantize_tree, tree_bytes


def measure_alpha(params: Any, bits: int = 8) -> Tuple[float, int, int]:
    """(alpha_w, fp_bytes, q_bytes) for weight quantization at ``bits``."""
    fp = tree_bytes(params)
    q = tree_bytes(quantize_tree(params, bits))
    return q / fp, fp, q


def synthetic_eval_batch(cfg: ModelConfig, batch: int = 4, seq: int = 128,
                         seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic token stream with Zipfian marginals (PPL eval stand-in)."""
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    # Zipf-ish: exponential rank distribution over the true vocab
    u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(-jnp.log(u) * cfg.vocab / 8.0).astype(jnp.int32)
    toks = jnp.clip(ranks, 0, cfg.vocab - 1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            k2, (batch, cfg.vlm.n_img_tokens, cfg.d_model)).astype(cfg.dtype)
    if cfg.family == "audio":
        out["audio_embeds"] = jax.random.normal(
            k2, (batch, cfg.encdec.n_audio_frames, cfg.d_model)
        ).astype(cfg.dtype)
    return out


def model_ppl(cfg: ModelConfig, params: Any,
              batch: Optional[Dict[str, jax.Array]] = None) -> float:
    model = build_model(cfg)
    batch = batch or synthetic_eval_batch(cfg)
    loss, _ = model.loss_fn(params, batch)
    return float(math.exp(float(loss)))


def measure_dppl(cfg: ModelConfig, params: Any, bits: int = 8,
                 batch: Optional[Dict[str, jax.Array]] = None
                 ) -> Tuple[float, float, float]:
    """(dPPL, ppl_fp, ppl_quant) with weight-only RTN at ``bits``."""
    batch = batch or synthetic_eval_batch(cfg)
    ppl_fp = model_ppl(cfg, params, batch)
    qparams = dequantize_tree(quantize_tree(params, bits))
    ppl_q = model_ppl(cfg, qparams, batch)
    return ppl_q - ppl_fp, ppl_fp, ppl_q


def calibrate(cfg: ModelConfig, params: Any, bits: int = 8,
              batch: Optional[Dict[str, jax.Array]] = None
              ) -> Dict[str, float]:
    """Measured (alpha_w, dPPL) record for this model + precision."""
    alpha, fp_bytes, q_bytes = measure_alpha(params, bits)
    dppl, ppl_fp, ppl_q = measure_dppl(cfg, params, bits, batch)
    return {"alpha_w": alpha, "fp_bytes": fp_bytes, "q_bytes": q_bytes,
            "dppl": dppl, "ppl_fp": ppl_fp, "ppl_quant": ppl_q,
            "bits": bits}
