"""Measured quantization effects: alpha (memory), beta (speed), dPPL.

The paper takes alpha/beta/dPPL from offline exhaustive evaluation ([10],
Table II).  Here all three are *measured* on the actual JAX models:

  * ``measure_alpha``  — bytes(quantized tree) / bytes(fp tree);
  * ``measure_beta``   — decode-throughput ratio tok/s(fp) / tok/s(method)
    timed on the REAL ServingEngine per (method, batch);
  * ``measure_dppl``   — perplexity difference between the fp and the
    weight-quantized model on a fixed synthetic eval set (real models would
    use WikiText; the machinery is identical).

``calibrate`` packages alpha/dPPL into a ``QuantMethod``-compatible record;
``calibrate_engine`` + ``measured_methods`` close the loop for the
SCHEDULER: the measured alpha/beta land in real ``QuantMethod`` records
(via the ``alpha_*_measured`` overrides and a ``beta`` replace), so every
``P2Coefficients`` and ``quant=auto`` descent runs on coefficients of the
engine that will actually serve the decision instead of the paper's table.
The table remains the default so the reproduction is exact.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.api import build_model
from repro.quant.ptq import dequantize_tree, quantize_tree, tree_bytes


def measure_alpha(params: Any, bits: int = 8) -> Tuple[float, int, int]:
    """(alpha_w, fp_bytes, q_bytes) for weight quantization at ``bits``."""
    fp = tree_bytes(params)
    q = tree_bytes(quantize_tree(params, bits))
    return q / fp, fp, q


def synthetic_eval_batch(cfg: ModelConfig, batch: int = 4, seq: int = 128,
                         seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic token stream with Zipfian marginals (PPL eval stand-in)."""
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    # Zipf-ish: exponential rank distribution over the true vocab
    u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(-jnp.log(u) * cfg.vocab / 8.0).astype(jnp.int32)
    toks = jnp.clip(ranks, 0, cfg.vocab - 1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            k2, (batch, cfg.vlm.n_img_tokens, cfg.d_model)).astype(cfg.dtype)
    if cfg.family == "audio":
        out["audio_embeds"] = jax.random.normal(
            k2, (batch, cfg.encdec.n_audio_frames, cfg.d_model)
        ).astype(cfg.dtype)
    return out


def model_ppl(cfg: ModelConfig, params: Any,
              batch: Optional[Dict[str, jax.Array]] = None) -> float:
    model = build_model(cfg)
    batch = batch or synthetic_eval_batch(cfg)
    loss, _ = model.loss_fn(params, batch)
    return float(math.exp(float(loss)))


def measure_dppl(cfg: ModelConfig, params: Any, bits: int = 8,
                 batch: Optional[Dict[str, jax.Array]] = None
                 ) -> Tuple[float, float, float]:
    """(dPPL, ppl_fp, ppl_quant) with weight-only RTN at ``bits``."""
    batch = batch or synthetic_eval_batch(cfg)
    ppl_fp = model_ppl(cfg, params, batch)
    qparams = dequantize_tree(quantize_tree(params, bits))
    ppl_q = model_ppl(cfg, qparams, batch)
    return ppl_q - ppl_fp, ppl_fp, ppl_q


def calibrate(cfg: ModelConfig, params: Any, bits: int = 8,
              batch: Optional[Dict[str, jax.Array]] = None
              ) -> Dict[str, float]:
    """Measured (alpha_w, dPPL) record for this model + precision."""
    alpha, fp_bytes, q_bytes = measure_alpha(params, bits)
    dppl, ppl_fp, ppl_q = measure_dppl(cfg, params, bits, batch)
    return {"alpha_w": alpha, "fp_bytes": fp_bytes, "q_bytes": q_bytes,
            "dppl": dppl, "ppl_fp": ppl_fp, "ppl_quant": ppl_q,
            "bits": bits}


# ---------------------------------------------------------------------------
# Measured beta: time the REAL engine per (method, batch)
# ---------------------------------------------------------------------------


def _time_tok_s(engine, prompts, caps, bits) -> float:
    """One timed generate() call -> emitted tokens per second."""
    t0 = time.perf_counter()
    result = engine.generate(prompts, n_tokens=caps, quant_bits=bits)
    dt = time.perf_counter() - t0
    return float(result.lengths.sum()) / max(dt, 1e-9)


def measure_beta(engine, methods: Optional[Sequence] = None,
                 batches: Sequence[int] = (1, 4, 8), iters: int = 3,
                 n_tokens: int = 32, prompt_len: int = 8,
                 min_batch: int = 4, seed: int = 0) -> Dict[str, Any]:
    """Measure beta (compute-time scale vs fp16) per (method, batch) on a
    real :class:`ServingEngine`.

    For every batch size, fp and the method's ``serve_bits`` are timed
    INTERLEAVED (fp, m, fp, m, ...) best-of-``iters`` — back-to-back
    pairs cancel machine-load drift, best-of cancels one-sided stalls.
    ``beta = tok_s(fp) / tok_s(method)`` (>1 ⇒ slower than fp); the
    scalar per-method beta is the median over batches >= ``min_batch``
    (small batches are latency-bound and noisy — the paper's beta is a
    throughput-regime number).  Both compilations are warmed before any
    timer starts.  Returns a JSON-able record (see ``measured_methods``).
    """
    from repro.core.quantization import METHODS
    methods = list(METHODS.values()) if methods is None else list(methods)
    rng = np.random.default_rng(seed)
    record: Dict[str, Any] = {"batches": [int(b) for b in batches],
                              "iters": int(iters),
                              "backend": jax.default_backend(),
                              "arch": engine.cfg.arch_id,
                              "methods": {}}
    for m in methods:
        per_batch, fp_per_batch, m_per_batch = {}, {}, {}
        for b in batches:
            nb = min(int(b), engine.batch_capacity)
            prompts = [rng.integers(1, engine.cfg.vocab,
                                    size=prompt_len).tolist()
                       for _ in range(nb)]
            caps = [n_tokens] * nb
            # warm both executables (compile + quantize-once) off-clock
            engine.generate(prompts, n_tokens=caps, quant_bits=0)
            engine.generate(prompts, n_tokens=caps, quant_bits=m.serve_bits)
            fp_best = q_best = 0.0
            for _ in range(iters):
                fp_best = max(fp_best,
                              _time_tok_s(engine, prompts, caps, 0))
                q_best = max(q_best, _time_tok_s(engine, prompts, caps,
                                                 m.serve_bits))
            per_batch[str(b)] = fp_best / q_best
            fp_per_batch[str(b)] = fp_best
            m_per_batch[str(b)] = q_best
        eligible = [per_batch[str(b)] for b in batches
                    if int(b) >= min_batch] or list(per_batch.values())
        record["methods"][m.name] = {
            "beta": float(np.median(eligible)),
            "per_batch": per_batch,
            "tok_s_fp": fp_per_batch,
            "tok_s": m_per_batch,
        }
    return record


def measure_swap_cost(engine, methods: Optional[Sequence] = None,
                      iters: int = 3, n_tokens: int = 2,
                      prompt_len: int = 4, seed: int = 0) -> Dict[str, Any]:
    """Measure the weight-swap latency between every pair of canonical
    serving precisions on a real :class:`ServingEngine`.

    A "swap" is what a split epoch pays between sub-batches: the engine
    re-serves through ``params_for`` with a different precision's tree
    from the multi-precision weight cache (plus the executable re-dispatch
    against the other donated buffers).  For every ordered pair ``a -> b``
    of distinct canonical bit specs the transition is timed INTERLEAVED
    best-of-``iters`` against its own stay-at-``b`` control:

        generate(a); T_swap = time(generate(b))     # swapped residency
        generate(b); T_stay = time(generate(b))     # warm residency

    ``swap_s = max(0, min T_swap - min T_stay)`` — back-to-back pairs
    cancel machine-load drift, best-of cancels one-sided stalls, and the
    stay control subtracts the cost of serving itself so only the
    transition overhead remains.  Both executables and every precision's
    cache entry are warmed off-clock first.  Methods sharing a canonical
    spec (e.g. W8A16/W8A8 on interpret backends, where
    ``_canon_bits`` folds (8, 8) -> 8) swap for free and get no pair.

    Returns a JSON-able record consumed by
    ``core.quantization.swap_seconds`` and the split descent
    (``core.dftsp.dftsp_schedule_split``); ``default_s`` is the worst
    measured pair, the fallback for unmeasured transitions.
    """
    from repro.core.quantization import METHODS
    methods = list(METHODS.values()) if methods is None else list(methods)
    canon = getattr(engine, "_canon_bits", lambda b: b)
    rng = np.random.default_rng(seed)
    nb = min(2, engine.batch_capacity)
    prompts = [rng.integers(1, engine.cfg.vocab, size=prompt_len).tolist()
               for _ in range(nb)]
    caps = [n_tokens] * nb

    by_key: Dict[str, Any] = {}
    names: Dict[str, str] = {}
    for m in methods:
        key = str(canon(m.serve_bits))
        names[m.name] = key
        by_key.setdefault(key, m.serve_bits)

    record: Dict[str, Any] = {"iters": int(iters),
                              "backend": jax.default_backend(),
                              "arch": engine.cfg.arch_id,
                              "batch": nb, "n_tokens": int(n_tokens),
                              "methods": names, "pairs": {},
                              "default_s": 0.0}
    # warm every precision's executable + weight-cache entry off-clock
    for bits in by_key.values():
        engine.generate(prompts, n_tokens=caps, quant_bits=bits)

    def _timed(bits) -> float:
        t0 = time.perf_counter()
        engine.generate(prompts, n_tokens=caps, quant_bits=bits)
        return time.perf_counter() - t0

    keys = sorted(by_key)
    for ka in keys:
        for kb in keys:
            if ka == kb:
                continue
            a, b = by_key[ka], by_key[kb]
            t_swap = t_stay = float("inf")
            for _ in range(iters):
                engine.generate(prompts, n_tokens=caps, quant_bits=a)
                t_swap = min(t_swap, _timed(b))
                engine.generate(prompts, n_tokens=caps, quant_bits=b)
                t_stay = min(t_stay, _timed(b))
            swap_s = max(0.0, t_swap - t_stay)
            record["pairs"][f"{ka}->{kb}"] = {
                "swap_s": swap_s, "t_swap": t_swap, "t_stay": t_stay}
            record["default_s"] = max(record["default_s"], swap_s)
    return record


def attach_alphas(record: Dict[str, Any], params: Any) -> Dict[str, Any]:
    """Add measured weight alphas (tree-bytes ratios) to a ``measure_beta``
    record in place, so the SAVED record fully determines the
    ``measured_methods`` reconstruction (the committed-artifact pinned
    tests rebuild methods from JSON alone, no re-timing)."""
    cache: Dict[int, float] = {}
    for name, meas in record["methods"].items():
        from repro.core.quantization import METHODS
        w = METHODS[name].weight_bits
        if w < 16:
            if w not in cache:
                cache[w] = measure_alpha(params, w)[0]
            meas["alpha_w"] = cache[w]
    return record


def measured_methods(record: Dict[str, Any],
                     round_to: float = 0.25) -> Dict[str, Any]:
    """Package a ``measure_beta`` record into real :class:`QuantMethod`
    records for the scheduler.

    Betas are snapped to a ``round_to`` grid: the scheduler's method
    ORDERING must not hang on run-to-run timing noise, so methods within
    the same grid cell are declared speed-equivalent and the descent
    falls through to the accuracy/memory axes (exactly what makes the
    measured coefficients change decisions — e.g. when W8A8 and W8A16
    measure at parity, W8A16's strictly better dPPL Pareto-dominates and
    W8A8 drops out of the candidate set).  Weight alphas come from the
    record when ``attach_alphas`` ran; ``alpha_a_measured`` is pinned at
    1.0 — the engine's KV/activation residency is fp unless the separate
    ``kv_bits`` path is on, which no weight method changes.
    """
    from repro.core.quantization import METHODS
    out = {}
    for name, meas in record["methods"].items():
        base = METHODS[name]
        beta = meas["beta"]
        if round_to > 0:
            beta = round(beta / round_to) * round_to
        kw: Dict[str, Any] = {"beta": float(beta)}
        if base.weight_bits < 16:
            kw["alpha_a_measured"] = 1.0
            if "alpha_w" in meas:
                kw["alpha_w_measured"] = float(meas["alpha_w"])
        out[name] = dataclasses.replace(base, **kw)
    return out
