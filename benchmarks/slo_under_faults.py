"""SLO-hardened serving under overload and injected faults (§2.4).

One frozen bursty trace (``BurstyGenerator``, non-homogeneous Poisson
with a diurnal swell plus a hand-placed burst, priorities 0-2, tight
deadline spread) is replayed through four admission stacks on the SAME
analytic continuous executor and ``dftsp`` policy:

  * ``fifo``      — arrival-order admission, no preemption: the
    historical baseline (``admission="fifo"``);
  * ``edf``       — EDF-within-priority admission plus the deadline
    gate (a candidate that cannot finish by its deadline even if served
    immediately never gets a slot — without the gate EDF collapses
    under overload, spending capacity on doomed tight-deadline work);
  * ``edf+preempt`` — plus priority preemption with spill/resume
    (capped at one eviction per request, 4-boundary backoff: more
    aggressive settings thrash);
  * ``hardened``  — plus the graceful-degradation controller (hysteresis
    on queue depth, shedding priority-0 work under sustained pressure).

Claim checked (deterministic counts on the frozen trace, so it gates in
CI): ``hardened`` beats ``fifo`` on p99 TTFT AND SLO attainment at
equal-or-better served req/s.

A second section re-runs the hardened stack under seeded ``FaultPlan``s
(transient step faults, with and without an injection cap) and asserts
the extended conservation equation
``arrived == served + dropped + shed + queued + in_flight`` holds for
every plan while the robustness counters (faults_injected, retried,
shed, quarantined) account for what the injector did.

  PYTHONPATH=src python -m benchmarks.slo_under_faults [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import DrainStallError
from repro.core.request import BurstyGenerator, ReplayGenerator
from repro.serving.faults import FaultPlan, FaultyExecutor
from repro.serving.runtime import (AnalyticContinuousExecutor,
                                   ContinuousRuntime)
from repro.serving.slo import DegradationController

N_EPOCHS = 12
CAPACITY = 8
K = 8
TRACE = dict(base_rate=12.0, seed=0, period=8.0, depth=0.6,
             bursts=((6.0, 6.0, 2.5),), tau_range=(0.5, 8.0),
             priorities=(0, 1, 2))

ARMS = [
    ("fifo", dict(admission="fifo")),
    ("edf", dict(admission="edf", deadline_gated=True)),
    ("edf+preempt", dict(admission="edf", deadline_gated=True,
                         preemption=True, max_preemptions=1,
                         backoff_boundaries=4)),
    ("hardened", dict(admission="edf", deadline_gated=True,
                      preemption=True, max_preemptions=1,
                      backoff_boundaries=4)),   # + degradation (built
                                                # per-run: stateful)
]

FAULT_PLANS = [
    ("transient-10%", FaultPlan(seed=7, p_transient=0.10)),
    ("transient-capped", FaultPlan(seed=7, p_transient=0.25,
                                   max_transient=40)),
    ("slow-segments", FaultPlan(seed=7, p_slow=0.05, slow_s=0.002)),
]


def _runtime(env, name, kw, plan=None):
    cexec = AnalyticContinuousExecutor(capacity=CAPACITY)
    if plan is not None:
        cexec = FaultyExecutor(cexec, plan)
    kw = dict(kw)
    if name == "hardened":
        kw["degradation"] = DegradationController(
            queue_high=16, queue_low=4, shed_below_priority=1)
    return ContinuousRuntime(env, "dftsp", cexec, k=K, **kw)


def _conserved(m):
    return m.arrived == m.served + m.dropped + m.shed \
        + len(m.final_queue_rids) + len(m.in_flight_rids)


def run(fast: bool = False, n_epochs: int = N_EPOCHS, seed: int = 0,
        quiet: bool = False):
    env = paper_env("bloom-3b")
    trace = dict(TRACE)
    trace["seed"] = seed
    gen = BurstyGenerator(horizon=(n_epochs - 1) * env.T_E, **trace)

    # -- SLO ladder on the frozen trace ----------------------------------
    rows, by_name = [], {}
    for name, kw in ARMS:
        rt = _runtime(env, name, kw)
        m = rt.run(gen=ReplayGenerator(gen.requests), n_epochs=n_epochs,
                   warmup_epochs=0)
        assert _conserved(m), f"{name}: conservation violated"
        by_name[name] = m
        rows.append([name, m.arrived, m.served, m.dropped, m.shed,
                     m.preempted, m.resumed,
                     round(m.slo_attainment, 3),
                     round(m.p99_ttft, 3), round(m.p50_ttft, 3),
                     round(m.p99_latency, 3),
                     round(m.throughput, 3)])

    hard, fifo = by_name["hardened"], by_name["fifo"]
    ok = (hard.served >= fifo.served
          and hard.p99_ttft < fifo.p99_ttft
          and hard.slo_attainment > fifo.slo_attainment)

    header = ["arm", "arrived", "served", "dropped", "shed", "preempted",
              "resumed", "slo_attain", "p99_ttft", "p50_ttft", "p99_lat",
              "req_s"]
    out = render(header, rows,
                 f"SLO ladder on frozen bursty trace (seed={seed}, "
                 f"{n_epochs} epochs, capacity={CAPACITY}, k={K})")
    if not quiet:
        print(out)

    # -- the hardened stack under injected faults ------------------------
    plans = FAULT_PLANS[:1] if fast else FAULT_PLANS
    fault_rows = []
    for pname, plan in plans:
        rt = _runtime(env, "hardened", dict(ARMS[3][1]), plan=plan)
        try:
            fm = rt.run(gen=ReplayGenerator(gen.requests),
                        n_epochs=n_epochs, warmup_epochs=0)
        except DrainStallError as e:      # partial metrics still usable
            fm = e.metrics
        assert _conserved(fm), f"{pname}: conservation violated"
        fault_rows.append([pname, fm.arrived, fm.served, fm.dropped,
                           fm.shed, fm.faults_injected, fm.retried,
                           len(fm.quarantined),
                           round(fm.slo_attainment, 3),
                           round(fm.throughput, 3)])
    fheader = ["plan", "arrived", "served", "dropped", "shed", "faults",
               "retried", "quarantined", "slo_attain", "req_s"]
    fout = render(fheader, fault_rows,
                  "hardened stack under injected faults (conservation "
                  "asserted per plan)")
    if not quiet:
        print(fout)

    save_table("slo_under_faults", header, rows,
               meta={"n_epochs": n_epochs, "capacity": CAPACITY, "k": K,
                     "trace": {k: str(v) for k, v in trace.items()},
                     "fast": fast, "fault_header": fheader,
                     "fault_rows": fault_rows,
                     "gate": {"hardened_beats_fifo": ok,
                              "fifo_p99_ttft": round(fifo.p99_ttft, 3),
                              "hardened_p99_ttft": round(hard.p99_ttft, 3),
                              "fifo_slo": round(fifo.slo_attainment, 3),
                              "hardened_slo":
                                  round(hard.slo_attainment, 3)}})
    print(f"[slo_under_faults] hardened beats fifo on p99 TTFT "
          f"({hard.p99_ttft:.3f} < {fifo.p99_ttft:.3f}), SLO attainment "
          f"({hard.slo_attainment:.3f} > {fifo.slo_attainment:.3f}) at "
          f"served {hard.served} >= {fifo.served}: "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="one fault plan (CI smoke)")
    args = ap.parse_args(argv)
    # deterministic counts on a frozen committed trace — gates in CI
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
