"""Run every benchmark (one per paper table/figure + the roofline report).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer epochs (CI smoke)")
    args = ap.parse_args(argv)
    n = 6 if args.fast else 16

    from benchmarks import (fig5a_throughput_vs_arrival as f5a,
                            fig5b_throughput_vs_latency as f5b,
                            fig6a_quant_precision as f6a,
                            fig6b_quant_accuracy as f6b,
                            fig6_adaptive as f6ad,
                            table3_pruning_complexity as t3,
                            multi_llm_throughput as ml,
                            multi_llm_continuous as mlc,
                            paged_vs_slab as pvs,
                            engine_decode as ed,
                            quant_kernels as qk,
                            calibration_flip as cf,
                            continuous_vs_epoch as cve,
                            slo_under_faults as suf,
                            roofline_report as rr)

    results = {}
    for name, mod, kw in (
            ("fig5a", f5a, {"n_epochs": n}),
            ("fig5b", f5b, {"n_epochs": n}),
            ("fig6a", f6a, {"n_epochs": n}),
            ("fig6b", f6b, {"n_epochs": n}),
            ("fig6_adaptive", f6ad, {"n_epochs": n}),
            ("table3", t3, {"n_epochs": max(4, n // 3)}),
            ("multi_llm", ml, {"n_epochs": max(6, n // 2)}),
            ("engine_decode", ed, {"fast": args.fast}),
            ("quant_kernels", qk, {"fast": args.fast}),
            ("calibration_flip", cf, {"fast": args.fast}),
            ("continuous", cve, {"fast": args.fast}),
            ("multi_continuous", mlc, {"fast": args.fast}),
            ("paged_vs_slab", pvs, {"fast": args.fast}),
            ("slo_faults", suf, {"fast": args.fast}),
            ("roofline", rr, {})):
        t0 = time.time()
        print(f"\n{'=' * 70}\n[bench] {name}\n{'=' * 70}")
        _, ok = mod.run(**kw)
        results[name] = ok
        print(f"[bench] {name} done in {time.time() - t0:.1f}s")

    print(f"\n{'=' * 70}")
    for k, v in results.items():
        print(f"  {k:10s} {'PASS' if v else 'FAIL'}")
    print(f"{'=' * 70}")
    return 0 if all(results.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
