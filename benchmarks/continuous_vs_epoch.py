"""Continuous batching vs the epoch-boundary protocol: req/s and tokens/s.

Both paths run the SAME frozen Poisson traffic (``ReplayGenerator``),
the same ``dftsp`` policy and the same reduced real engine:

  * ``epoch``      — ``EpochRuntime`` + ``EngineExecutor``: admission only
    at epoch boundaries, one fused decode per scheduled batch (the
    paper's Fig. 2 protocol);
  * ``continuous`` — ``ContinuousRuntime`` + ``EngineContinuousExecutor``:
    the same queue lifecycle, but the cohort decodes in chunked segments
    of ``k`` tokens and freed slots are refilled at EVERY segment
    boundary (``policy.validate()``-gated, so P1 feasibility still holds
    for everything resident).

Sweeps arrival rate x chunk size and emits
``experiments/benchmarks/continuous_vs_epoch.json`` (CI uploads the
--fast datapoint per PR).  Claim checked (deterministic request COUNTS,
not wall-clock, so it gates in CI too): at the highest swept arrival
rate, continuous admission serves >= 1.2x the epoch baseline's req/s.
The win has two sources the motivation names: slots freed by early
finishers (short caps, early EOS) are refilled mid-epoch, and a drained
cohort restarts immediately instead of idling until the next boundary.

  PYTHONPATH=src python -m benchmarks.continuous_vs_epoch [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.request import ReplayGenerator
from repro.serving.engine import tiny_engine
from repro.serving.runtime import (ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime)

RATES = [2.0, 4.0, 8.0, 16.0]
CHUNKS = [1, 2, 4, 8, 16]
LENGTHS = (4, 8, 16)        # output caps, heterogeneous so rows free early
B, S_MAX, N_MAX = 8, 16, 16
SPEEDUP_FLOOR = 1.2         # acceptance: continuous >= 1.2x req/s at the
                            # highest arrival rate


def _engine(params=None, seed=0):
    return tiny_engine("bloom-3b", params=params, batch_capacity=B,
                       s_max=S_MAX, n_max=N_MAX, seed=seed)


def run(fast: bool = False, n_epochs: int = 8, seed: int = 0,
        quiet: bool = False):
    rates = [2.0, 8.0] if fast else RATES
    chunks = [2] if fast else CHUNKS
    # --fast trims the sweep, not the horizon: short runs leave the
    # served counts dominated by cohort end effects
    env = paper_env("bloom-3b", "W8A16")

    eng = _engine(seed=seed)
    params = eng._raw_params            # share weights across every run
    rows = []
    for rate in rates:
        # freeze the stream at the epoch baseline's LAST admission
        # boundary, (n_epochs-1)*T_E: the continuous grid's finer
        # interior windows then replay exactly the same offered load
        # (no tail arrivals only one protocol can see)
        traffic = ReplayGenerator.poisson(
            rate, (n_epochs - 1) * env.T_E, seed=seed, lengths=LENGTHS)
        base = EpochRuntime(env, "dftsp",
                            EngineExecutor(_engine(params), seed=seed)).run(
            gen=ReplayGenerator(traffic.requests), n_epochs=n_epochs,
            seed=seed, warmup_epochs=0)
        for k in chunks:
            rt = ContinuousRuntime(
                env, "dftsp",
                EngineContinuousExecutor(_engine(params), seed=seed), k=k)
            cont = rt.run(gen=ReplayGenerator(traffic.requests),
                          n_epochs=n_epochs, seed=seed, warmup_epochs=0)
            assert cont.arrived == cont.served + cont.dropped \
                + len(cont.final_queue_rids)
            rows.append([rate, k, rt.segments_per_epoch,
                         base.served, cont.served,
                         round(base.throughput, 3),
                         round(cont.throughput, 3),
                         round(cont.served / max(base.served, 1), 2),
                         cont.admitted_mid_epoch,
                         round(cont.mean_occupancy, 2),
                         round(base.tokens_per_s, 1),
                         round(cont.tokens_per_s, 1)])

    header = ["rate", "k", "seg_per_epoch", "epoch_served", "cont_served",
              "epoch_req_s", "cont_req_s", "speedup", "mid_epoch_admits",
              "occupancy", "epoch_tok_s", "cont_tok_s"]
    out = render(header, rows,
                 "Continuous batching vs epoch-boundary protocol "
                 f"({n_epochs} epochs, B={B}, n_max={N_MAX})")
    if not quiet:
        print(out)
    top = max(rates)
    at_top = [r for r in rows if r[0] == top]
    ok = bool(at_top) and max(r[7] for r in at_top) >= SPEEDUP_FLOOR
    save_table("continuous_vs_epoch", header, rows,
               meta={"n_epochs": n_epochs, "batch_capacity": B,
                     "s_max": S_MAX, "n_max": N_MAX, "lengths": LENGTHS,
                     "fast": fast, "speedup_floor": SPEEDUP_FLOOR,
                     "floor_met_at_top_rate": ok})
    print(f"[continuous_vs_epoch] continuous >= {SPEEDUP_FLOOR}x epoch "
          f"req/s at rate {top}: {'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="two rates, one chunk size (CI smoke)")
    args = ap.parse_args(argv)
    # the gate compares deterministic served-request COUNTS on frozen
    # traffic (not wall-clock), so it holds on hosted CI runners too
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
