"""Fig. 6b: throughput vs user accuracy demand under W4A16 GPTQ vs
ZeroQuant-Local (paper Table II dPPL values).

Paper's claims: relaxing the accuracy constraint admits more requests;
GPTQ (lower dPPL) sustains higher throughput than ZQ-Local on the same
model; both capped by the W8A16 dotted line.
"""
from __future__ import annotations

import math

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

ACC_CAPS = [0.9, 0.7, 0.5, 0.3, 0.0]     # max accuracy demand in the pool
MODELS = ["bloom-3b", "opt-13b"]
RATE = 100


def run(n_epochs: int = 16, seed: int = 0, quiet: bool = False):
    rows = []
    for model in MODELS:
        for cap in ACC_CAPS:
            row = [model, cap]
            for method in ("W4A16-GPTQ", "W4A16-ZQL", "W8A16"):
                env = paper_env(model, method)
                gen = RequestGenerator(rate=RATE, seed=seed,
                                       acc_range=(0.0, cap))
                runtime = EpochRuntime(env, get_policy("dftsp"),
                                       AnalyticExecutor())
                res = runtime.run(n_epochs=n_epochs, seed=seed, gen=gen)
                row.append(round(res.throughput, 3))
            rows.append(row)
    header = ["model", "max_acc_demand", "GPTQ", "ZQ-Local", "W8A16(ref)"]
    out = render(header, rows,
                 "Fig 6b: throughput vs accuracy demand (W4A16)")
    if not quiet:
        print(out)
    save_table("fig6b", header, rows)

    ok = True
    for model in MODELS:
        sub = [r for r in rows if r[0] == model]
        # GPTQ >= ZQ-Local (lower dPPL passes more accuracy filters)
        if not all(r[2] >= r[3] - 0.3 for r in sub):
            ok = False
            print(f"  CLAIM VIOLATION GPTQ>=ZQL for {model}")
        # relaxing accuracy (cap -> 0) never reduces throughput
        if sub[-1][2] + 0.3 < sub[0][2]:
            ok = False
            print(f"  CLAIM VIOLATION relax-accuracy for {model}")
    print(f"[fig6b] paper-claim checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
