"""Beyond-paper: multi-LLM edge node throughput vs traffic split.

One EN hosts BLOOM-3B + BLOOM-7.1B; the request stream splits between
them.  Shows the joint scheduler's behaviour as heavy-model traffic
grows — the single-T_C queueing cost the paper's single-model framing
never surfaces.

Runs through the SAME EpochRuntime as every single-model benchmark:
``multi-dftsp`` is a registered SchedulerPolicy, so the multi-LLM node
gets queue carryover, aging and viability drops for free.
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv
from repro.core.policy import get_policy
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

SPLITS = [0.0, 0.25, 0.5, 0.75, 1.0]     # fraction of traffic to 7.1B
RATE = 40
MODELS = ("bloom-3b", "bloom-7b1")


def run(n_epochs: int = 10, seed: int = 0, quiet: bool = False):
    menv = MultiLLMEnv.host({m: paper_env(m, "W8A16") for m in MODELS})
    policy = get_policy("multi-dftsp")
    rows = []
    for split in SPLITS:
        owner = {}

        def tagger(arrivals, split=split, owner=owner):
            # rid-stride split: unbiased in arrival time (an index slice
            # would hand one model only the oldest requests, since
            # arrivals are time-sorted)
            for r in arrivals:
                r.model_id = MODELS[1] if r.rid % 4 < round(split * 4) \
                    else MODELS[0]
                owner[r.rid] = r.model_id
            return arrivals

        m = EpochRuntime(menv, policy, AnalyticExecutor()).run(
            rate=RATE, n_epochs=n_epochs, seed=seed, warmup_epochs=0,
            tag_arrivals=tagger)
        served = {mid: 0 for mid in MODELS}
        for t in m.traces:
            if not t.counted:
                continue
            for rid in t.selected_rids:
                served[owner[rid]] += 1
        rows.append([f"{split:.2f}", served[MODELS[0]], served[MODELS[1]],
                     m.served, round(m.throughput, 2)])
    header = ["frac_to_7b1", "served_3b", "served_7b1", "total", "req/s"]
    out = render(header, rows, "Multi-LLM node: throughput vs traffic split")
    if not quiet:
        print(out)
    save_table("multi_llm", header, rows)
    # sanity: all-3b traffic must beat all-7b1 traffic (smaller model)
    ok = rows[0][4] >= rows[-1][4]
    print(f"[multi_llm] checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
