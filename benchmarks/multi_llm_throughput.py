"""Beyond-paper: multi-LLM edge node throughput vs traffic split.

One EN hosts BLOOM-3B + BLOOM-7.1B; the request stream splits between
them.  Shows the joint scheduler's behaviour as heavy-model traffic
grows — the single-T_C queueing cost the paper's single-model framing
never surfaces.
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, multi_dftsp, tag
from repro.core.request import RequestGenerator

SPLITS = [0.0, 0.25, 0.5, 0.75, 1.0]     # fraction of traffic to 7.1B
RATE = 40


def run(n_epochs: int = 10, seed: int = 0, quiet: bool = False):
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })
    rows = []
    for split in SPLITS:
        served = {"bloom-3b": 0, "bloom-7b1": 0}
        gen = RequestGenerator(rate=RATE, seed=seed)
        for e in range(n_epochs):
            reqs = gen.within(e * 2.0, (e + 1) * 2.0)
            cut = int(len(reqs) * (1 - split))
            pool = tag(reqs[:cut], "bloom-3b") + tag(reqs[cut:], "bloom-7b1")
            sched, _ = multi_dftsp(menv, pool)
            for mid, batch in sched.items():
                served[mid] += len(batch)
        total = sum(served.values())
        rows.append([f"{split:.2f}", served["bloom-3b"],
                     served["bloom-7b1"], total,
                     round(total / (n_epochs * 2.0), 2)])
    header = ["frac_to_7b1", "served_3b", "served_7b1", "total", "req/s"]
    out = render(header, rows, "Multi-LLM node: throughput vs traffic split")
    if not quiet:
        print(out)
    save_table("multi_llm", header, rows)
    # sanity: all-3b traffic must beat all-7b1 traffic (smaller model)
    ok = rows[0][4] >= rows[-1][4]
    print(f"[multi_llm] checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
