"""Table III: tree-search complexity reduction from pruning + ordering.

Paper's claims: reduction grows with arrival rate; >=45% at rate 10,
~98% at rate 200.  Complexity is measured in visited tree nodes
(hardware-independent, exactly what the pruning eliminates).

Both searchers see the same slack-ranked candidate pool capped at
POOL_CAP requests per epoch (an admission prefilter): without it the
un-pruned search is not merely slower, it is computationally infeasible
at rate >= 100 — which over-proves the paper's point but never finishes.
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.dftsp import dftsp_schedule
from repro.core.environment import paper_env
from repro.core.policy import CallablePolicy
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

RATES = [10, 50, 100, 200]
POOL_CAP = 36


def _capped(env, reqs, **kw):
    pool = sorted(reqs, key=lambda r: r.tau - r.t_w, reverse=True)[:POOL_CAP]
    return dftsp_schedule(env, pool, **kw)


def _fast(env, reqs):
    return _capped(env, reqs)


def _slow(env, reqs):
    return _capped(env, reqs, prune=False, order_desc=False,
                   fast_z_bound=False)


def run(n_epochs: int = 6, seed: int = 0, quiet: bool = False):
    env = paper_env("bloom-3b", "W8A16")
    rows = []
    for rate in RATES:
        fast = EpochRuntime(env, CallablePolicy(_fast), AnalyticExecutor()) \
            .run(rate=rate, n_epochs=n_epochs, seed=seed)
        slow = EpochRuntime(env, CallablePolicy(_slow), AnalyticExecutor()) \
            .run(rate=rate, n_epochs=n_epochs, seed=seed)
        assert fast.served == slow.served, "pruning changed the optimum!"
        red = 1.0 - fast.nodes_visited / max(slow.nodes_visited, 1)
        rows.append([rate, slow.nodes_visited, fast.nodes_visited,
                     f"{100 * red:.2f}%"])
    header = ["arrival_rate", "brute_nodes", "dftsp_nodes", "reduction"]
    out = render(header, rows, "Table III: tree-pruning complexity reduction")
    if not quiet:
        print(out)
    save_table("table3", header, rows)

    reds = [float(r[3][:-1]) for r in rows]
    ok = reds[0] >= 45.0 and all(b >= a - 5.0 for a, b in zip(reds, reds[1:]))
    print(f"[table3] paper-claim checks (>=45% @10, grows with rate): "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
