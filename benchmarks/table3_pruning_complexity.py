"""Table III: tree-search complexity reduction from pruning + ordering.

Paper's claims: reduction grows with arrival rate; >=45% at rate 10,
~98% at rate 200.  Complexity is measured in visited tree nodes
(hardware-independent, exactly what the pruning eliminates) AND in mean
wall-clock per ``dftsp_schedule`` call, so scheduler perf regressions
show up in ``table3.json`` even when node counts stay flat.

Both searchers see the same slack-ranked candidate pool capped at
POOL_CAP requests per epoch (an admission prefilter): without it the
un-pruned search is not merely slower, it is computationally infeasible
at rate >= 100 — which over-proves the paper's point but never finishes.
"""
from __future__ import annotations

import time

from benchmarks.common import render, save_table
from repro.core.dftsp import dftsp_schedule
from repro.core.environment import paper_env
from repro.core.policy import CallablePolicy
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

RATES = [10, 50, 100, 200]
POOL_CAP = 36


def _timed(times, **kw):
    """A capped-pool scheduler that appends each call's wall-clock to
    ``times`` (seconds per ``dftsp_schedule`` invocation)."""
    def sched(env, reqs):
        pool = sorted(reqs, key=lambda r: r.tau - r.t_w,
                      reverse=True)[:POOL_CAP]
        t0 = time.perf_counter()
        out = dftsp_schedule(env, pool, **kw)
        times.append(time.perf_counter() - t0)
        return out
    return sched


def _ms(times) -> float:
    return 1e3 * sum(times) / max(len(times), 1)


def run(n_epochs: int = 6, seed: int = 0, quiet: bool = False):
    env = paper_env("bloom-3b", "W8A16")
    rows = []
    for rate in RATES:
        fast_t: list = []
        slow_t: list = []
        fast = EpochRuntime(env, CallablePolicy(_timed(fast_t)),
                            AnalyticExecutor()) \
            .run(rate=rate, n_epochs=n_epochs, seed=seed)
        slow = EpochRuntime(env, CallablePolicy(_timed(
            slow_t, prune=False, order_desc=False, fast_z_bound=False)),
            AnalyticExecutor()) \
            .run(rate=rate, n_epochs=n_epochs, seed=seed)
        assert fast.served == slow.served, "pruning changed the optimum!"
        red = 1.0 - fast.nodes_visited / max(slow.nodes_visited, 1)
        rows.append([rate, slow.nodes_visited, fast.nodes_visited,
                     f"{100 * red:.2f}%",
                     round(_ms(slow_t), 3), round(_ms(fast_t), 3)])
    header = ["arrival_rate", "brute_nodes", "dftsp_nodes", "reduction",
              "brute_ms_per_call", "dftsp_ms_per_call"]
    out = render(header, rows, "Table III: tree-pruning complexity reduction")
    if not quiet:
        print(out)
    save_table("table3", header, rows)

    reds = [float(r[3][:-1]) for r in rows]
    ok = reds[0] >= 45.0 and all(b >= a - 5.0 for a, b in zip(reds, reds[1:]))
    print(f"[table3] paper-claim checks (>=45% @10, grows with rate): "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
