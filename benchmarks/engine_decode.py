"""Engine decode microbenchmark: fused device-resident loop vs legacy host loop.

Sweeps batch size x quant method x model family on reduced engines and
records tokens/s for both decode paths:

  * ``fused``  — ``ServingEngine.generate``: prefill + ONE jitted
    ``lax.while_loop`` (greedy sampling, EOS, caps all on device; one
    host→device and one device→host transfer per batch);
  * ``legacy`` — ``ServingEngine.generate_reference``: the historical
    Python loop that blocks on a device→host argmax EVERY token.

Emits ``experiments/benchmarks/engine_decode.json`` so the perf
trajectory of the data plane is recorded per PR (CI uploads it as an
artifact).  Claim checked: the fused loop is >= 3x legacy tokens/s at
batch_capacity=8 on CPU — on the host loop each token pays Python
dispatch + a blocking transfer, which is exactly the ``t_A`` the paper's
throughput objective says must run at hardware speed.

The engines are deliberately TINY (1-2 layers, d_model 64, short
prompts): this benchmark measures the decode LOOP, so per-step model
compute must not drown the per-token host overhead being eliminated.
The >=3x floor therefore applies to the full-precision dense rows (the
pure loop-overhead datapoint); quantized rows additionally measure the
interpret-mode Pallas dequant-matmul on CPU and the recurrent families
their heavier step graphs — recorded for the trajectory, not gated.

Each row also records ``speedup_vs_fp`` (fused tok/s at this precision
over fused tok/s at fp in the same engine/batch): quantization must not
COST throughput.  That is gated: W8 >= ~1x fp at batch >= 4 on the dense
family, measured by a dedicated interleaved best-of pass (see
``W8_PARITY_FLOOR``), deterministic enough for CI.

  PYTHONPATH=src python -m benchmarks.engine_decode [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import render, save_table
from repro.config import get_arch
from repro.serving.engine import ServingEngine

# reduced per-family engines (CPU-scale, loop-overhead-dominated)
FAMILIES = {
    "dense": ("bloom-3b", dict(n_layers=1, d_model=64, n_heads=2,
                               n_kv_heads=2, d_ff=128, vocab=256)),
    "ssm": ("xlstm-1.3b", dict(n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, vocab=256)),
    "hybrid": ("zamba2-7b", dict(n_layers=4, d_model=64, n_heads=2,
                                 n_kv_heads=2, d_ff=128, vocab=256)),
}
BATCHES = [1, 4, 8]
QUANTS = [0, 8, 4]      # weight bits (0 = full precision)
S_MAX, N_MAX = 16, 64
SPEEDUP_FLOOR = 3.0     # acceptance: fused >= 3x legacy at B=8 (dense fp)
# acceptance: "quantization must pay" — serving W8 may not cost throughput
# vs full precision at batch >= 4 on the dense family.  On interpret
# backends the engine dequantizes at load (int8 matmuls LOSE to the f32
# BLAS on CPU), so the ratio is parity-by-construction and the gate is
# deterministic up to timer noise; the floor absorbs that noise (~±7%
# per ~30ms sample on a busy host — the guarded regression is the old
# 0.28x state, not percent-level drift).  On TPU the same gate demands
# a real int8 win.
W8_PARITY_FLOOR = 0.9


def _tok_s(fn, prompts, caps, bits, iters: int):
    fn(prompts, caps, quant_bits=bits)                  # warmup / compile
    tokens = 0
    t0 = time.perf_counter()
    for _ in range(iters):
        tokens += int(fn(prompts, caps, quant_bits=bits).lengths.sum())
    return tokens / (time.perf_counter() - t0), tokens // iters


def _one_tok_s(fn, prompts, caps, bits, calls: int = 2):
    tokens = 0
    t0 = time.perf_counter()
    for _ in range(calls):
        tokens += int(fn(prompts, caps, quant_bits=bits).lengths.sum())
    return tokens / (time.perf_counter() - t0)


def _w8_parity(eng, prompts, caps, iters: int) -> float:
    """Best-of-N W8/fp throughput ratio, timed INTERLEAVED
    (fp, w8, fp, w8, ...) so machine-load drift hits both sides equally
    and one-sided stalls are discarded by the best-of.  N is fixed well
    above the table-timing ``iters``: each call is ~10ms, and the floor
    needs both bests to have converged to the true per-call max."""
    eng.generate(prompts, caps, quant_bits=0)           # warm both
    eng.generate(prompts, caps, quant_bits=8)
    fp_best = q_best = 0.0
    for _ in range(max(iters, 8)):
        fp_best = max(fp_best, _one_tok_s(eng.generate, prompts, caps, 0))
        q_best = max(q_best, _one_tok_s(eng.generate, prompts, caps, 8))
    return q_best / fp_best


def run(fast: bool = False, seed: int = 0, quiet: bool = False):
    families = ["dense"] if fast else list(FAMILIES)
    batches = [8] if fast else BATCHES
    quants = [0, 8] if fast else QUANTS
    iters = 2 if fast else 5
    rng = np.random.default_rng(seed)

    rows = []
    parity = {}             # dense-family batch -> W8/fp throughput ratio
    for fam in families:
        arch, red = FAMILIES[fam]
        cfg = get_arch(arch).scaled(**red)
        params = None
        for B in batches:
            # eos_id=-1: no token ever matches, so every row decodes its
            # full cap — a deterministic token count for the timing
            eng = ServingEngine(cfg, params=params, batch_capacity=B,
                                s_max=S_MAX, n_max=N_MAX, eos_id=-1,
                                seed=seed)
            params = eng._raw_params        # share weights across batch sizes
            prompts = [rng.integers(1, cfg.vocab, size=S_MAX // 2).tolist()
                       for _ in range(B)]
            caps = [N_MAX] * B
            fp_fused = None
            for bits in quants:
                fused, n_tok = _tok_s(eng.generate, prompts, caps, bits,
                                      iters)
                legacy, _ = _tok_s(eng.generate_reference, prompts, caps,
                                   bits, iters)
                if bits == 0:
                    fp_fused = fused
                rows.append([fam, arch, B, bits, n_tok,
                             round(fused, 1), round(legacy, 1),
                             round(fused / legacy, 2),
                             round(fused / fp_fused, 2)])
            if fam == "dense" and B >= 4 and 8 in quants:
                parity[B] = round(_w8_parity(eng, prompts, caps, iters), 3)

    header = ["family", "arch", "batch", "weight_bits", "tokens_per_call",
              "fused_tok_s", "legacy_tok_s", "speedup", "speedup_vs_fp"]
    out = render(header, rows,
                 "Engine decode: fused while_loop vs legacy host loop")
    if not quiet:
        print(out)
    at_cap = [r for r in rows if r[0] == "dense" and r[2] == 8 and r[3] == 0]
    ok_loop = bool(at_cap) and all(r[7] >= SPEEDUP_FLOOR for r in at_cap)
    ok_w8 = bool(parity) and all(v >= W8_PARITY_FLOOR
                                 for v in parity.values())
    save_table("engine_decode", header, rows,
               meta={"s_max": S_MAX, "n_max": N_MAX, "iters": iters,
                     "fast": fast, "speedup_floor": SPEEDUP_FLOOR,
                     "floor_met_at_batch8": ok_loop,
                     "w8_parity_floor": W8_PARITY_FLOOR,
                     "w8_parity": {str(k): v for k, v in parity.items()},
                     "w8_parity_ok": ok_w8})
    print(f"[engine_decode] fused >= {SPEEDUP_FLOOR}x legacy at batch 8 "
          f"(dense, full precision): {'PASS' if ok_loop else 'FAIL'}")
    print(f"[engine_decode] W8 >= {W8_PARITY_FLOOR}x fp tok/s at "
          f"batch >= 4 (dense): {parity} "
          f"{'PASS' if ok_w8 else 'FAIL'}")
    # hosted CI runners are too noisy to gate merges on the fused-vs-legacy
    # timing ratio, so --fast records that datapoint without gating; the W8
    # parity gate is deterministic (interleaved best-of ratio of the SAME
    # computation on interpret backends) and gates everywhere.
    return rows, (ok_loop or fast) and ok_w8


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="dense family only, batch 8 (CI smoke)")
    args = ap.parse_args(argv)
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
