"""Engine decode microbenchmark: fused device-resident loop vs legacy host loop.

Sweeps batch size x quant method x model family on reduced engines and
records tokens/s for both decode paths:

  * ``fused``  — ``ServingEngine.generate``: prefill + ONE jitted
    ``lax.while_loop`` (greedy sampling, EOS, caps all on device; one
    host→device and one device→host transfer per batch);
  * ``legacy`` — ``ServingEngine.generate_reference``: the historical
    Python loop that blocks on a device→host argmax EVERY token.

Emits ``experiments/benchmarks/engine_decode.json`` so the perf
trajectory of the data plane is recorded per PR (CI uploads it as an
artifact).  Claim checked: the fused loop is >= 3x legacy tokens/s at
batch_capacity=8 on CPU — on the host loop each token pays Python
dispatch + a blocking transfer, which is exactly the ``t_A`` the paper's
throughput objective says must run at hardware speed.

The engines are deliberately TINY (1-2 layers, d_model 64, short
prompts): this benchmark measures the decode LOOP, so per-step model
compute must not drown the per-token host overhead being eliminated.
The >=3x floor therefore applies to the full-precision dense rows (the
pure loop-overhead datapoint); quantized rows additionally measure the
interpret-mode Pallas dequant-matmul on CPU and the recurrent families
their heavier step graphs — recorded for the trajectory, not gated.

  PYTHONPATH=src python -m benchmarks.engine_decode [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import render, save_table
from repro.config import get_arch
from repro.serving.engine import ServingEngine

# reduced per-family engines (CPU-scale, loop-overhead-dominated)
FAMILIES = {
    "dense": ("bloom-3b", dict(n_layers=1, d_model=64, n_heads=2,
                               n_kv_heads=2, d_ff=128, vocab=256)),
    "ssm": ("xlstm-1.3b", dict(n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, vocab=256)),
    "hybrid": ("zamba2-7b", dict(n_layers=4, d_model=64, n_heads=2,
                                 n_kv_heads=2, d_ff=128, vocab=256)),
}
BATCHES = [1, 4, 8]
QUANTS = [0, 8, 4]      # weight bits (0 = full precision)
S_MAX, N_MAX = 16, 64
SPEEDUP_FLOOR = 3.0     # acceptance: fused >= 3x legacy at B=8 (dense fp)


def _tok_s(fn, prompts, caps, bits, iters: int):
    fn(prompts, caps, quant_bits=bits)                  # warmup / compile
    tokens = 0
    t0 = time.perf_counter()
    for _ in range(iters):
        tokens += int(fn(prompts, caps, quant_bits=bits).lengths.sum())
    return tokens / (time.perf_counter() - t0), tokens // iters


def run(fast: bool = False, seed: int = 0, quiet: bool = False):
    families = ["dense"] if fast else list(FAMILIES)
    batches = [8] if fast else BATCHES
    quants = [0, 8] if fast else QUANTS
    iters = 2 if fast else 5
    rng = np.random.default_rng(seed)

    rows = []
    for fam in families:
        arch, red = FAMILIES[fam]
        cfg = get_arch(arch).scaled(**red)
        params = None
        for B in batches:
            # eos_id=-1: no token ever matches, so every row decodes its
            # full cap — a deterministic token count for the timing
            eng = ServingEngine(cfg, params=params, batch_capacity=B,
                                s_max=S_MAX, n_max=N_MAX, eos_id=-1,
                                seed=seed)
            params = eng._raw_params        # share weights across batch sizes
            prompts = [rng.integers(1, cfg.vocab, size=S_MAX // 2).tolist()
                       for _ in range(B)]
            caps = [N_MAX] * B
            for bits in quants:
                fused, n_tok = _tok_s(eng.generate, prompts, caps, bits,
                                      iters)
                legacy, _ = _tok_s(eng.generate_reference, prompts, caps,
                                   bits, iters)
                rows.append([fam, arch, B, bits, n_tok,
                             round(fused, 1), round(legacy, 1),
                             round(fused / legacy, 2)])

    header = ["family", "arch", "batch", "weight_bits", "tokens_per_call",
              "fused_tok_s", "legacy_tok_s", "speedup"]
    out = render(header, rows,
                 "Engine decode: fused while_loop vs legacy host loop")
    if not quiet:
        print(out)
    at_cap = [r for r in rows if r[0] == "dense" and r[2] == 8 and r[3] == 0]
    ok = bool(at_cap) and all(r[7] >= SPEEDUP_FLOOR for r in at_cap)
    save_table("engine_decode", header, rows,
               meta={"s_max": S_MAX, "n_max": N_MAX, "iters": iters,
                     "fast": fast, "speedup_floor": SPEEDUP_FLOOR,
                     "floor_met_at_batch8": ok})
    print(f"[engine_decode] fused >= {SPEEDUP_FLOOR}x legacy at batch 8 "
          f"(dense, full precision): {'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="dense family only, batch 8 (CI smoke)")
    args = ap.parse_args(argv)
    _, ok = run(fast=args.fast)
    # hosted CI runners are too noisy to gate merges on a timing ratio:
    # --fast records the datapoint (uploaded as an artifact) but only the
    # full local run is authoritative for the floor
    return 0 if (ok or args.fast) else 1


if __name__ == "__main__":
    sys.exit(main())
