"""Quantized-matmul kernel microbenchmark: the three precision tiers.

Times ``kernels.ops.quant_matmul`` (Pallas) per variant x shape against
the f32 ``jnp.dot`` baseline:

  * ``W8A16`` — int8 weights dequantized in-kernel, f32 accumulate;
  * ``W8A8``  — int8 weights x dynamically row-quantized int8
    activations, int8xint8 dot with int32 accumulation, one rescale at
    writeout (the tier where quantization PAYS on int8-capable MXUs);
  * ``W4A16`` — packed int4 weights, index-free even/odd unpack + f32
    accumulate.

Emits ``experiments/benchmarks/quant_kernels.json`` so per-kernel cost
is tracked per PR next to the end-to-end engine_decode numbers.  On CPU
the kernels run under the Pallas interpreter — absolute times are
emulation costs and the ratios are recorded for the trajectory, not
gated (the serving engine dequantizes at load on interpret backends for
exactly this reason).  On TPU the same table measures the real MXU
paths.

  PYTHONPATH=src python -m benchmarks.quant_kernels [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import render, save_table
from repro.kernels import ops
from repro.quant.ptq import quantize

# (M, K, N): decode-shaped (skinny M), prefill-shaped, and a ragged
# remainder shape exercising the padding path
SHAPES = [(8, 256, 256), (128, 512, 512), (64, 384, 200)]
VARIANTS = [("W8A16", 8, 16), ("W8A8", 8, 8), ("W4A16", 4, 16)]


def _best_us(fn, iters: int) -> float:
    fn()                                    # warmup / compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(fast: bool = False, seed: int = 0, quiet: bool = False):
    shapes = SHAPES[:1] if fast else SHAPES
    iters = 3 if fast else 10
    rng = jax.random.PRNGKey(seed)

    rows = []
    for (m, k, n) in shapes:
        kx, kw = jax.random.split(jax.random.fold_in(rng, m * n))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32) / jnp.sqrt(k)
        fp_us = _best_us(
            lambda: jnp.dot(x, w).block_until_ready(), iters)
        rows.append([f"{m}x{k}x{n}", "f32", round(fp_us, 1), 1.0])
        for name, bits, act_bits in VARIANTS:
            qt = quantize(w, bits, act_bits=act_bits)
            us = _best_us(
                lambda: ops.qmatmul(x, qt).block_until_ready(), iters)
            rows.append([f"{m}x{k}x{n}", name, round(us, 1),
                         round(us / fp_us, 2)])

    header = ["shape", "variant", "best_us", "vs_f32"]
    out = render(header, rows, "quant_matmul kernel tiers vs f32 dot")
    if not quiet:
        print(out)
    ok = all(r[2] > 0 for r in rows)        # sanity: every variant ran
    save_table("quant_kernels", header, rows,
               meta={"backend": jax.default_backend(),
                     "interpret": ops.INTERPRET, "iters": iters,
                     "fast": fast})
    print(f"[quant_kernels] {len(rows)} datapoints on "
          f"{jax.default_backend()} (interpret={ops.INTERPRET}): "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="single shape, fewer iters (CI smoke)")
    args = ap.parse_args(argv)
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
