"""Calibration flip: Table-II coefficients vs measured-beta coefficients.

The paper's scheduler prices quantization with offline Table-II numbers
(W8A8 beta=0.7: int8 compute is ~1.4x faster than fp16 on the paper's
Jetson testbed).  This repo can instead MEASURE alpha/beta on the very
engine that will serve the decision (``quant.calibration.measure_beta``)
and feed the measured coefficients into every ``quant=auto`` descent.

This benchmark demonstrates that the feedback loop is not a no-op: on a
backend where W8A8 does NOT pay (e.g. CPU interpret mode, where the
engine dequantizes at load and all methods time at parity), the measured
betas snap to the same grid cell, W8A16 Pareto-dominates W8A8 on dPPL,
and ``dftsp_schedule_auto`` picks a different method for the SAME queue
than it does under Table II.

Emits ``experiments/benchmarks/calibration_flip.json``.  The committed
artifact carries the full ``measure_beta`` record (betas + measured
alphas), so ``tests/test_calibration.py`` can rebuild the measured
method set from JSON alone — no re-timing — and pin the flip forever.

  PYTHONPATH=src python -m benchmarks.calibration_flip [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.config import get_arch
from repro.core.dftsp import dftsp_schedule_auto
from repro.core.environment import paper_env
from repro.core.quantization import METHODS
from repro.core.request import RequestGenerator
from repro.quant.calibration import (attach_alphas, measure_beta,
                                     measured_methods)
from repro.serving.engine import ServingEngine

ARCH = "bloom-3b"
REDUCED = dict(n_layers=1, d_model=64, n_heads=2, n_kv_heads=2,
               d_ff=128, vocab=256)
S_MAX, N_MAX = 16, 32
QUEUE_SEEDS = [0, 1, 2]
QUEUE_RATE, QUEUE_HORIZON = 25.0, 2.0


def make_queue(seed: int):
    """Deterministic request queue over the paper's length/accuracy mix."""
    gen = RequestGenerator(rate=QUEUE_RATE, seed=seed)
    return gen.within(0.0, QUEUE_HORIZON)


def decide(env, queue, methods=None):
    batch, method, _ = dftsp_schedule_auto(env, queue, methods=methods)
    return method.name, len(batch)


def run(fast: bool = False, seed: int = 0, quiet: bool = False):
    batches = (4,) if fast else (1, 4, 8)
    iters = 2 if fast else 3

    cfg = get_arch(ARCH).scaled(**REDUCED)
    eng = ServingEngine(cfg, batch_capacity=max(batches), s_max=S_MAX,
                        n_max=N_MAX, eos_id=-1, seed=seed)
    record = measure_beta(eng, methods=list(METHODS.values()),
                          batches=batches, iters=iters,
                          n_tokens=N_MAX // 2, prompt_len=S_MAX // 2,
                          seed=seed)
    attach_alphas(record, eng._raw_params)
    measured = measured_methods(record)

    env = paper_env(ARCH, "W8A16")
    rows = []
    for qseed in QUEUE_SEEDS:
        queue = make_queue(qseed)
        t2_name, t2_batch = decide(env, queue)
        m_name, m_batch = decide(env, queue, methods=list(measured.values()))
        rows.append([qseed, len(queue), t2_name, t2_batch, m_name, m_batch,
                     t2_name != m_name])

    header = ["queue_seed", "n_queue", "table2_method", "table2_batch",
              "measured_method", "measured_batch", "flipped"]
    out = render(header, rows,
                 "quant=auto decisions: Table II vs measured coefficients")
    if not quiet:
        print(out)
    n_flips = sum(1 for r in rows if r[6])
    ok = n_flips >= 1
    save_table("calibration_flip", header, rows,
               meta={"arch": ARCH, "reduced": REDUCED, "fast": fast,
                     "queue": {"rate": QUEUE_RATE, "horizon": QUEUE_HORIZON,
                               "seeds": QUEUE_SEEDS},
                     "record": record,
                     "snapped_betas": {n: m.beta for n, m in
                                       measured.items()},
                     "n_flips": n_flips})
    print(f"[calibration_flip] measured coefficients changed "
          f"{n_flips}/{len(rows)} quant=auto decisions: "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="single batch size, fewer timing iters (CI smoke)")
    args = ap.parse_args(argv)
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
