"""Split epochs: per-batch quantization splits vs the best single method.

The paper's scheduler picks ONE quantization method per epoch; the split
extension (DESIGN.md §1.1) lets the (z, method) descent serve one
epoch's queue as two sequential sub-batches at different precisions,
with the measured weight-swap latency between them charged in the P2
epoch time.  The win is real when a queue mixes accuracy demands: the
tight-accuracy tail that forced the whole batch onto a conservative
method (or out of the batch entirely) rides in its own sub-batch while
the bulk serves at the fast precision.

This benchmark freezes the paper's request mix over several queue seeds
and compares, per queue:

  * the best SINGLE-method schedule (max batch over every Table-II
    method — a stronger baseline than ``quant=auto``, which also
    optimizes compute time);
  * the split schedule priced with a swap record MEASURED on a real
    ``ServingEngine`` (``quant.calibration.measure_swap_cost``).

Gate: the split never loses (ratio >= 1.0 on every queue — a descent
that includes the no-split candidate can't) and strictly wins on at
least one queue (ratio >= 1.1 somewhere), with the measured swap cost
charged.

Emits ``experiments/benchmarks/quant_splits.json``.  The committed
artifact carries the full swap record, so ``tests/test_quant_splits.py``
re-derives every decision from JSON alone — no re-timing — and pins the
win forever.

  PYTHONPATH=src python -m benchmarks.quant_splits [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.config import get_arch
from repro.core.dftsp import dftsp_schedule, dftsp_schedule_split
from repro.core.environment import paper_env
from repro.core.quantization import METHODS
from repro.core.request import RequestGenerator
from repro.quant.calibration import measure_swap_cost
from repro.serving.engine import ServingEngine

ARCH = "bloom-3b"
REDUCED = dict(n_layers=1, d_model=64, n_heads=2, n_kv_heads=2,
               d_ff=128, vocab=256)
S_MAX, N_MAX = 16, 32
QUEUE_SEEDS = [0, 1, 2, 3]
QUEUE_SEEDS_FAST = [0, 2]
QUEUE_RATE, QUEUE_HORIZON = 25.0, 2.0
GATE_FLOOR, GATE_WIN = 1.0, 1.1


def make_queue(seed: int):
    """Deterministic request queue over the paper's length/accuracy mix."""
    gen = RequestGenerator(rate=QUEUE_RATE, seed=seed)
    return gen.within(0.0, QUEUE_HORIZON)


def best_single(env, queue):
    """The best single-method schedule: max batch over every method
    (ties to the first, i.e. Table-II order)."""
    name, size = None, -1
    for m in METHODS.values():
        batch, _ = dftsp_schedule(env, queue, quant=m)
        if len(batch) > size:
            name, size = m.name, len(batch)
    return name, size


def split_plan(env, queue, swap_record=None):
    """Split schedule -> (total requests, [(n_sub, method), ...])."""
    subs, _ = dftsp_schedule_split(env, queue, swap_record=swap_record)
    return sum(len(b) for b, _ in subs), [(len(b), m.name) for b, m in subs]


def run(fast: bool = False, seed: int = 0, quiet: bool = False):
    cfg = get_arch(ARCH).scaled(**REDUCED)
    eng = ServingEngine(cfg, batch_capacity=4, s_max=S_MAX, n_max=N_MAX,
                        eos_id=-1, seed=seed)
    record = measure_swap_cost(eng, iters=1 if fast else 3, seed=seed)

    env = paper_env(ARCH, "W8A16")
    rows = []
    for qseed in (QUEUE_SEEDS_FAST if fast else QUEUE_SEEDS):
        queue = make_queue(qseed)
        s_name, s_batch = best_single(env, queue)
        free_total, _ = split_plan(env, queue)
        total, plan = split_plan(env, queue, swap_record=record)
        ratio = total / s_batch if s_batch else 1.0
        rows.append([qseed, len(queue), s_name, s_batch, free_total,
                     total, " + ".join(f"{n}@{m}" for n, m in plan),
                     round(ratio, 3)])

    header = ["queue_seed", "n_queue", "single_method", "single_batch",
              "split_free", "split_measured", "split_plan", "ratio"]
    out = render(header, rows,
                 "split epochs vs best single method (measured swap cost)")
    if not quiet:
        print(out)
    ratios = [r[7] for r in rows]
    ok = all(r >= GATE_FLOOR for r in ratios) and \
        any(r >= GATE_WIN for r in ratios)
    save_table("quant_splits", header, rows,
               meta={"arch": ARCH, "reduced": REDUCED, "fast": fast,
                     "queue": {"rate": QUEUE_RATE, "horizon": QUEUE_HORIZON,
                               "seeds": [r[0] for r in rows]},
                     "record": record,
                     "gate": {"floor": GATE_FLOOR, "win": GATE_WIN}})
    print(f"[quant_splits] split vs best single: ratios {ratios} "
          f"(floor {GATE_FLOOR}, win {GATE_WIN} somewhere): "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer queue seeds + timing iters (CI smoke)")
    args = ap.parse_args(argv)
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
