"""Fig. 5b: throughput vs user latency requirement.

Paper's claims: throughput rises as deadlines loosen; BLOOM-3B > 7.1B;
NoB struggles hardest under tight deadlines on the larger model.
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

# the paper's tau domain is [0.5, 2.0]; beyond it NoB overtakes batching
# (lone requests run unpadded => cheaper per the paper's own cost model) —
# recorded as a beyond-paper observation in EXPERIMENTS.md §Repro-notes.
TAU_RANGES = [(0.5, 0.75), (0.75, 1.0), (1.0, 1.5), (1.5, 2.0)]
SCHEDS = ["dftsp", "stb", "nob"]
MODELS = ["bloom-3b", "bloom-7b1"]
RATE = 50


def run(n_epochs: int = 20, seed: int = 0, quiet: bool = False):
    rows = []
    for model in MODELS:
        env = paper_env(model, "W8A16")
        for tau in TAU_RANGES:
            row = [model, f"{tau[0]}-{tau[1]}s"]
            for s in SCHEDS:
                gen = RequestGenerator(rate=RATE, seed=seed, tau_range=tau)
                runtime = EpochRuntime(env, get_policy(s), AnalyticExecutor())
                res = runtime.run(n_epochs=n_epochs, seed=seed, gen=gen)
                row.append(round(res.throughput, 3))
            rows.append(row)
    header = ["model", "tau", *SCHEDS]
    out = render(header, rows, "Fig 5b: throughput (req/s) vs latency req")
    if not quiet:
        print(out)
    save_table("fig5b", header, rows)

    ok = True
    for model in MODELS:
        sub = [r for r in rows if r[0] == model]
        # looser deadlines never hurt (allow small MC noise)
        if sub[-1][2] + 0.25 < sub[0][2]:
            ok = False
            print(f"  CLAIM VIOLATION throughput vs tau for {model}")
        for r in sub:
            if r[2] + 1e-9 < max(r[3], r[4]):
                ok = False
                print(f"  CLAIM VIOLATION dftsp best at {r}")
    print(f"[fig5b] paper-claim checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
