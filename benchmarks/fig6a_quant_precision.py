"""Fig. 6a: throughput vs quantization precision (accuracy ignored).

Paper's claims: lower precision => higher throughput (memory + beta);
larger models handle fewer requests at equal precision.
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

METHODS = ["W16A16", "W8A16", "W4A16-GPTQ"]
MODELS = ["bloom-3b", "bloom-7b1", "opt-13b"]
RATE = 100


def run(n_epochs: int = 16, seed: int = 0, quiet: bool = False):
    rows = []
    for model in MODELS:
        row = [model]
        for m in METHODS:
            env = paper_env(model, m)
            # accuracy ignored in 6a: all users accept any dPPL
            gen = RequestGenerator(rate=RATE, seed=seed, acc_range=(0.0, 0.0))
            runtime = EpochRuntime(env, get_policy("dftsp"),
                                   AnalyticExecutor())
            res = runtime.run(n_epochs=n_epochs, seed=seed, gen=gen)
            row.append(round(res.throughput, 3))
        rows.append(row)
    header = ["model", *METHODS]
    out = render(header, rows, "Fig 6a: throughput vs quantization precision")
    if not quiet:
        print(out)
    save_table("fig6a", header, rows)

    ok = True
    for r in rows:
        if not (r[1] <= r[2] + 0.3 and r[2] <= r[3] + 0.3):
            ok = False
            print(f"  CLAIM VIOLATION precision ordering at {r}")
    for i in range(len(METHODS)):
        col = [r[i + 1] for r in rows]
        if not (col[0] >= col[1] >= col[2]):
            ok = False
            print(f"  CLAIM VIOLATION size ordering for {METHODS[i]}")
    print(f"[fig6a] paper-claim checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
