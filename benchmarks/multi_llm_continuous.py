"""Multi-LLM continuous serving on real engines: the joint policy vs the
per-model epoch baseline.

One edge node hosts TWO real reduced engines (BLOOM-3B + BLOOM-7.1B
scaled to CPU size) behind a ``MultiLLMEnv``; both protocols run the
SAME frozen Poisson traffic (``ReplayGenerator``) randomly split across
the hosted models (``random_tagger`` — stateless, so the two protocols'
different time slicing sees identical splits):

  * ``epoch``      — ``EpochRuntime`` + ``EngineExecutor``: the joint
    ``multi-dftsp`` schedule at epoch boundaries, one fused decode per
    scheduled per-model batch;
  * ``continuous`` — ``ContinuousRuntime`` + ``EngineContinuousExecutor``:
    one device-resident cohort PER HOSTED ENGINE, admission at every
    chunked-segment boundary gated by the policy oracle AND the joint
    ``multi_feasible`` re-check (the runtime raises
    ``InfeasibleDecisionError`` if any admitted joint batch fails it, so
    a completed run certifies node-wide P1 feasibility), with each fresh
    cohort's quantization method picked by the ``quant=auto`` descent
    and served through the engine's multi-precision weight cache.

Sweeps arrival rate x chunk size and emits
``experiments/benchmarks/multi_llm_continuous.json`` (CI uploads the
--fast datapoint per PR).  Claim checked (deterministic request COUNTS
on frozen traffic, so it gates in CI): at the highest swept arrival
rate, the continuous multi-engine node serves >= 1.2x the per-model
epoch baseline's req/s, and per-cohort ``quant=auto`` selections appear
in ``EpochTrace.quants``.

  PYTHONPATH=src python -m benchmarks.multi_llm_continuous [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, random_tagger
from repro.core.request import ReplayGenerator
from repro.serving.engine import tiny_engine
from repro.serving.runtime import (ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime)

HOSTED = ("bloom-3b", "bloom-7b1")
RATES = [4.0, 8.0, 16.0]
CHUNKS = [2, 4, 8]
LENGTHS = (4, 8, 16)        # output caps, heterogeneous so rows free early
B, S_MAX, N_MAX = 8, 16, 16
SPEEDUP_FLOOR = 1.2         # acceptance: continuous >= 1.2x req/s at the
                            # highest arrival rate


def _engines(params=None, seed=0):
    """Two real reduced engines, one per hosted model.  ``params`` shares
    each arch's weights across runs so baseline and continuous serve
    identical models."""
    return {arch: tiny_engine(
        arch, params=None if params is None else params[arch],
        batch_capacity=B, s_max=S_MAX, n_max=N_MAX, seed=seed)
        for arch in HOSTED}


def run(fast: bool = False, n_epochs: int = 8, seed: int = 0,
        quiet: bool = False):
    rates = [8.0] if fast else RATES
    chunks = [2] if fast else CHUNKS
    menv = MultiLLMEnv.host({m: paper_env(m, "W8A16") for m in HOSTED})
    tagger = random_tagger(sorted(menv.envs), seed=seed)

    first = _engines(seed=seed)
    params = {m: e._raw_params for m, e in first.items()}
    rows = []
    quants_seen: set = set()
    occupancy_series: dict = {}
    for rate in rates:
        # freeze the stream at the epoch baseline's LAST admission
        # boundary so the continuous grid's finer interior windows
        # replay exactly the same offered load
        traffic = ReplayGenerator.poisson(
            rate, (n_epochs - 1) * menv.T_E, seed=seed, lengths=LENGTHS)
        base = EpochRuntime(
            menv, "multi-dftsp",
            EngineExecutor(_engines(params, seed), seed=seed)).run(
            gen=ReplayGenerator(traffic.requests), n_epochs=n_epochs,
            seed=seed, warmup_epochs=0, tag_arrivals=tagger)
        for k in chunks:
            rt = ContinuousRuntime(
                menv, "multi-dftsp:quant=auto",
                EngineContinuousExecutor(_engines(params, seed), seed=seed),
                k=k)
            # a completed run certifies every admitted joint batch passed
            # multi_feasible: the runtime re-checks each admission and
            # raises InfeasibleDecisionError otherwise
            cont = rt.run(gen=ReplayGenerator(traffic.requests),
                          n_epochs=n_epochs, seed=seed, warmup_epochs=0,
                          tag_arrivals=tagger)
            assert cont.arrived == cont.served + cont.dropped \
                + len(cont.final_queue_rids)
            epoch_quants = [t.quants for t in cont.traces if t.quants]
            assert epoch_quants, "quant=auto cohorts must record methods"
            quants_seen.update(q for tq in epoch_quants
                               for q in tq.values())
            # the full per-segment series, not just the scalar mean —
            # paged_vs_slab and the plots need the shape of the
            # occupancy trajectory, and means hide the drain tail
            occupancy_series[f"rate{rate:g}_k{k}"] = [
                round(o, 4) for t in cont.traces if t.counted
                for o in t.occupancy]
            rows.append([rate, k, rt.segments_per_epoch,
                         base.served, cont.served,
                         round(base.throughput, 3),
                         round(cont.throughput, 3),
                         round(cont.served / max(base.served, 1), 2),
                         cont.admitted_mid_epoch,
                         round(cont.mean_occupancy, 2),
                         " ".join(f"{m}:{n}" for m, n in
                                  sorted(cont.served_by_model.items())),
                         " ".join(sorted(cont.served_by_method))])

    header = ["rate", "k", "seg_per_epoch", "epoch_served", "cont_served",
              "epoch_req_s", "cont_req_s", "speedup", "mid_epoch_admits",
              "occupancy", "served_by_model", "methods"]
    out = render(header, rows,
                 "Multi-LLM continuous serving (2 engines, joint "
                 f"admission, quant=auto; {n_epochs} epochs, B={B} per "
                 f"engine, n_max={N_MAX})")
    if not quiet:
        print(out)
    top = max(rates)
    at_top = [r for r in rows if r[0] == top]
    ok = bool(at_top) and max(r[7] for r in at_top) >= SPEEDUP_FLOOR
    save_table("multi_llm_continuous", header, rows,
               meta={"n_epochs": n_epochs, "hosted": list(HOSTED),
                     "batch_capacity": B, "s_max": S_MAX, "n_max": N_MAX,
                     "lengths": LENGTHS, "fast": fast,
                     "speedup_floor": SPEEDUP_FLOOR,
                     "floor_met_at_top_rate": ok,
                     "quants_selected": sorted(quants_seen),
                     "occupancy_series": occupancy_series})
    print(f"[multi_llm_continuous] continuous >= {SPEEDUP_FLOOR}x epoch "
          f"req/s at rate {top}: {'PASS' if ok else 'FAIL'} "
          f"(methods selected: {sorted(quants_seen)})")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="one rate, one chunk size (CI smoke)")
    args = ap.parse_args(argv)
    # the gate compares deterministic served-request COUNTS on frozen
    # traffic (not wall-clock), so it holds on hosted CI runners too
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
