"""Paged KV arena vs contiguous slabs on the multi-LLM continuous node.

Same two-engine edge node and the SAME frozen Poisson traffic as
``benchmarks/multi_llm_continuous.py`` (``ReplayGenerator`` + stateless
``random_tagger``, so both data planes see identical offered load),
served twice through ``ContinuousRuntime`` + ``EngineContinuousExecutor``:

  * ``slab``  — each cohort owns a contiguous (B, s_max + n_max) cache;
    block accounting is slot-level, so "block occupancy" is just the
    occupied-slot fraction (the 0.12-0.19 the paged design attacks);
  * ``paged`` — one node-wide :class:`KVArena` (DESIGN.md §2.3) sized to
    ``SHRINK`` x the summed slab page count, CAP-AWARE per-block
    admission reservations with incremental segment-boundary lease
    top-ups (the ``topups`` column), leases returned the moment rows
    finish.

Claim checked (deterministic request COUNTS on frozen traffic, so it
gates in CI): at the highest swept arrival rate the paged node's mean
block occupancy is STRICTLY above the slab baseline's, while serving at
least the slab's req/s — i.e. the arena runs the same traffic from
``SHRINK`` x the physical KV memory with denser pages and no throughput
loss.  Fragmentation (allocated-but-dead tokens inside leased pages) is
reported alongside.

Emits ``experiments/benchmarks/paged_vs_slab.json`` (CI uploads the
--fast datapoint per PR).

  PYTHONPATH=src python -m benchmarks.paged_vs_slab [--fast]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, random_tagger
from repro.core.request import ReplayGenerator
from repro.serving.engine import tiny_engine
from repro.serving.kv_arena import KVArena
from repro.serving.runtime import ContinuousRuntime, EngineContinuousExecutor

HOSTED = ("bloom-3b", "bloom-7b1")
RATES = [4.0, 8.0, 16.0]
LENGTHS = (4, 8, 16)        # output caps, heterogeneous so rows free early
B, S_MAX, N_MAX = 8, 16, 16
K = 2                       # admission every 2 decode steps
BLOCK_TOKENS = 8            # cache_len = 32 -> 4 logical blocks per row
SHRINK = 0.5                # arena = HALF the slab KV footprint — the
                            # cap-aware incremental leasing headroom
                            # (worst-case leasing only sustained 0.625)


def _engines(params=None, seed=0):
    return {arch: tiny_engine(
        arch, params=None if params is None else params[arch],
        batch_capacity=B, s_max=S_MAX, n_max=N_MAX, seed=seed)
        for arch in HOSTED}


def _serve(menv, tagger, traffic, n_epochs, seed, params, arena=None):
    engines = _engines(params, seed)
    pool = None
    if arena is not None:
        pool = KVArena.for_engines(engines, block_tokens=BLOCK_TOKENS,
                                   shrink=SHRINK)
    ex = EngineContinuousExecutor(engines, seed=seed, arena=pool)
    m = ContinuousRuntime(menv, "multi-dftsp", ex, k=K).run(
        gen=ReplayGenerator(traffic.requests), n_epochs=n_epochs,
        seed=seed, warmup_epochs=0, tag_arrivals=tagger)
    assert m.arrived == m.served + m.dropped + len(m.final_queue_rids)
    if pool is not None:
        # every lease must be back on the free list after the drain
        assert pool.free_pages == pool.total_pages, \
            (pool.free_pages, pool.total_pages)
    return m, pool


def run(fast: bool = False, n_epochs: int = 8, seed: int = 0,
        quiet: bool = False):
    rates = [RATES[-1]] if fast else RATES
    menv = MultiLLMEnv.host({m: paper_env(m, "W8A16") for m in HOSTED})
    tagger = random_tagger(sorted(menv.envs), seed=seed)
    first = _engines(seed=seed)
    params = {m: e._raw_params for m, e in first.items()}

    rows = []
    series: dict = {}
    for rate in rates:
        traffic = ReplayGenerator.poisson(
            rate, (n_epochs - 1) * menv.T_E, seed=seed, lengths=LENGTHS)
        slab, _ = _serve(menv, tagger, traffic, n_epochs, seed, params)
        paged, pool = _serve(menv, tagger, traffic, n_epochs, seed,
                             params, arena=True)
        series[f"rate{rate:g}"] = {
            "slab_occupancy": [round(o, 4) for t in slab.traces
                               if t.counted for o in t.occupancy],
            "paged_blocks_in_use": [u for t in paged.traces if t.counted
                                    for u in t.kv_blocks_in_use],
            "paged_blocks_total": pool.n_pages and pool.total_pages}
        rows.append([rate, slab.served, paged.served,
                     round(slab.throughput, 3), round(paged.throughput, 3),
                     round(slab.mean_block_occupancy, 3),
                     round(paged.mean_block_occupancy, 3),
                     round(paged.fragmentation, 3),
                     pool.total_pages, pool.alloc_peak,
                     paged.kv_topup_pages])

    header = ["rate", "slab_served", "paged_served", "slab_req_s",
              "paged_req_s", "slab_block_occ", "paged_block_occ",
              "paged_frag", "arena_pages", "alloc_peak", "topups"]
    out = render(header, rows,
                 f"Paged KV arena vs contiguous slabs ({n_epochs} epochs, "
                 f"B={B} per engine, block_tokens={BLOCK_TOKENS}, "
                 f"arena={SHRINK:g}x slab memory)")
    if not quiet:
        print(out)
    top = max(rates)
    at_top = [r for r in rows if r[0] == top]
    ok = bool(at_top) and all(
        r[6] > r[5] and r[2] >= r[1] for r in at_top)
    save_table("paged_vs_slab", header, rows,
               meta={"n_epochs": n_epochs, "hosted": list(HOSTED),
                     "batch_capacity": B, "s_max": S_MAX, "n_max": N_MAX,
                     "lengths": LENGTHS, "k": K, "fast": fast,
                     "block_tokens": BLOCK_TOKENS, "shrink": SHRINK,
                     "gate_met_at_top_rate": ok,
                     "occupancy_series": series})
    print(f"[paged_vs_slab] paged block occupancy > slab AND req/s >= "
          f"slab at rate {top:g} from {SHRINK:g}x memory: "
          f"{'PASS' if ok else 'FAIL'}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="top rate only (CI smoke)")
    args = ap.parse_args(argv)
    # deterministic served-request counts on frozen traffic — holds on
    # hosted CI runners
    _, ok = run(fast=args.fast)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
