"""Render the §Roofline table from the dry-run JSON artifacts."""
from __future__ import annotations

import json
import os

from benchmarks.common import render, save_table

HERE = os.path.dirname(__file__)
DRYRUN = os.path.join(HERE, "..", "experiments")


def load(name: str):
    path = os.path.join(DRYRUN, name)
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def run(quiet: bool = False):
    rows = []
    for fname in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        data = load(fname)
        if data is None:
            print(f"[roofline] missing {fname} — run "
                  f"`python -m repro.launch.dryrun` first")
            continue
        for r in data["results"]:
            rows.append([
                r["arch"], r["shape"], r["mesh"],
                round(r["bytes_per_device"] / 2 ** 30, 2), r["fits"],
                f"{r['t_compute']:.2e}", f"{r['t_memory']:.2e}",
                f"{r['t_collective']:.2e}", r["bottleneck"][2:],
                round(r["useful_compute_ratio"], 3),
            ])
    header = ["arch", "shape", "mesh", "GiB/dev", "fits", "t_comp",
              "t_mem", "t_coll", "bottleneck", "useful"]
    out = render(header, rows, "Roofline terms per (arch x shape x mesh)")
    if not quiet:
        print(out)
    save_table("roofline", header, rows)
    return rows, True


if __name__ == "__main__":
    run()
