"""Shared benchmark plumbing: result tables + text rendering."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "benchmarks")


def save_table(name: str, header: List[str], rows: List[List],
               meta: Dict | None = None) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as fh:
        json.dump({"header": header, "rows": rows, "meta": meta or {}}, fh,
                  indent=1)
    return path


def render(header: Sequence, rows: Sequence[Sequence], title: str = "") -> str:
    widths = [max(len(str(h)), *(len(_fmt(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append("  ".join(str(h).rjust(w) for h, w in zip(header, widths)))
    for r in rows:
        lines.append("  ".join(_fmt(v).rjust(w) for v, w in zip(r, widths)))
    return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3g}" if abs(v) < 1e4 else f"{v:.3e}"
    return str(v)
