"""Fig. 5a: throughput vs arrival rate, DFTSP vs StB vs NoB,
BLOOM-3B vs BLOOM-7.1B (W8A16 default quantization).

Paper's claims to validate:
  * throughput grows with arrival rate then saturates (edge constraints);
  * DFTSP > StB > NoB at every rate;
  * BLOOM-7.1B < BLOOM-3B throughput (larger model).
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

RATES = [5, 10, 25, 50, 100, 250]
SCHEDS = ["dftsp", "stb", "nob"]
MODELS = ["bloom-3b", "bloom-7b1"]


def run(n_epochs: int = 20, seed: int = 0, quiet: bool = False):
    rows = []
    for model in MODELS:
        env = paper_env(model, "W8A16")
        for rate in RATES:
            row = [model, rate]
            for s in SCHEDS:
                runtime = EpochRuntime(env, get_policy(s), AnalyticExecutor())
                res = runtime.run(rate=rate, n_epochs=n_epochs, seed=seed)
                row.append(round(res.throughput, 3))
            rows.append(row)
    header = ["model", "rate", *SCHEDS]
    out = render(header, rows, "Fig 5a: throughput (req/s) vs arrival rate")
    if not quiet:
        print(out)
    save_table("fig5a", header, rows)

    # paper-claim checks
    ok = True
    for model in MODELS:
        sub = [r for r in rows if r[0] == model]
        for r in sub:
            if not (r[2] >= r[3] - 1e-9 and r[2] >= r[4] - 1e-9):
                ok = False
                print(f"  CLAIM VIOLATION dftsp>=stb,nob at {r}")
        if not (sub[-1][2] >= sub[0][2]):
            ok = False
    b3 = sum(r[2] for r in rows if r[0] == "bloom-3b")
    b7 = sum(r[2] for r in rows if r[0] == "bloom-7b1")
    if b7 > b3:
        ok = False
        print("  CLAIM VIOLATION bloom-7.1b should be slower")
    print(f"[fig5a] paper-claim checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
