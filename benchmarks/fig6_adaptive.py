"""Fig. 6 (adaptive): quantization-method selection as a per-epoch
scheduling decision (beyond-paper — the refactor's headline scenario).

The paper sweeps fixed methods offline (Fig. 6a/6b); here
``dftsp:quant=auto`` chooses the throughput-optimal admissible method
per epoch.  Claims checked:

  * adaptive throughput >= every fixed METHODS deployment on the same
    workload (the (z, method) descent is optimal per epoch);
  * on accuracy-heterogeneous workloads the adaptive policy actually
    MIXES methods across epochs (it is a live decision, not a sweep).
"""
from __future__ import annotations

from benchmarks.common import render, save_table
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.core.quantization import METHODS
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime

MODELS = ["bloom-3b", "opt-13b"]
ACC_MIXES = [(0.0, 1.0), (0.5, 1.0), (0.9, 1.0)]   # accuracy-demand ranges
RATE = 60


def _run(env, spec, acc_range, n_epochs, seed):
    gen = RequestGenerator(rate=RATE, seed=seed, acc_range=acc_range)
    return EpochRuntime(env, get_policy(spec), AnalyticExecutor()).run(
        n_epochs=n_epochs, seed=seed, gen=gen)


def run(n_epochs: int = 16, seed: int = 0, quiet: bool = False):
    rows = []
    ok = True
    mixed_anywhere = False
    for model in MODELS:
        env = paper_env(model)
        for acc in ACC_MIXES:
            fixed = {name: _run(env, f"dftsp:quant={name}", acc,
                                n_epochs, seed).throughput
                     for name in METHODS}
            auto = _run(env, "dftsp:quant=auto", acc, n_epochs, seed)
            best_name = max(fixed, key=fixed.get)
            mix = "+".join(sorted(auto.served_by_method)) or "-"
            mixed_anywhere |= len(auto.served_by_method) >= 2
            rows.append([model, f"a~U{acc}", round(auto.throughput, 3),
                         round(fixed[best_name], 3), best_name, mix])
            if auto.throughput + 1e-9 < fixed[best_name]:
                ok = False
                print(f"  CLAIM VIOLATION auto<fixed for {model} {acc}")
    if not mixed_anywhere:
        ok = False
        print("  CLAIM VIOLATION adaptive policy never mixed methods")

    header = ["model", "acc_demand", "auto_thr", "best_fixed_thr",
              "best_fixed", "methods_served"]
    out = render(header, rows,
                 "Fig 6 (adaptive): per-epoch method selection vs "
                 "fixed deployments")
    if not quiet:
        print(out)
    save_table("fig6_adaptive", header, rows,
               meta={"rate": RATE, "n_epochs": n_epochs, "seed": seed})
    print(f"[fig6_adaptive] paper-claim checks: {'PASS' if ok else 'FAIL'}")
    return rows, ok


if __name__ == "__main__":
    run()
