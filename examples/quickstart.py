"""Quickstart: the paper in 60 seconds.

1. Build the edge environment (paper §IV testbed: 20x Jetson TX2).
2. Generate one epoch of Poisson requests.
3. Schedule with DFTSP vs the baselines and compare.
4. Execute the DFTSP batch on a real (reduced) JAX BLOOM model.

  PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import numpy as np

from repro.config import get_arch
from repro.core import problem
from repro.core.environment import paper_env
from repro.core.request import RequestGenerator
from repro.core.schedulers import SCHEDULERS
from repro.serving.engine import ServingEngine


def main():
    # -- 1. environment -----------------------------------------------------
    env = paper_env("bloom-3b", quant="W8A16")
    print(f"edge node: C={env.C:.2e} FLOP/s, M={env.M / 1e9:.0f} GB, "
          f"{env.n_units} units, quant={env.quant.name} "
          f"(alpha_w={env.quant.alpha_w}, beta={env.quant.beta})")

    # -- 2. one epoch of requests -------------------------------------------
    gen = RequestGenerator(rate=25.0, seed=0)
    requests = gen.within(0.0, env.T_E)
    print(f"\n{len(requests)} requests arrived in one {env.T_E}s epoch:")
    for r in requests[:5]:
        print(f"  <s={r.s}, n={r.n}, tau={r.tau:.2f}s, a={r.a:.2f}>")
    if len(requests) > 5:
        print(f"  ... and {len(requests) - 5} more")

    # -- 3. schedule --------------------------------------------------------
    print("\nscheduler comparison (one epoch):")
    chosen = []
    for name in ("dftsp", "greedy", "stb", "nob"):
        sel, stats = SCHEDULERS[name](env, requests)
        tag = ""
        if name == "dftsp":
            chosen = sel
            tag = f"  (optimal; {stats.nodes_visited} nodes searched)"
        print(f"  {name:8s} schedules {len(sel):2d} requests{tag}")
    assert problem.feasible(env, chosen)

    # -- 4. run the batch on a real JAX model -------------------------------
    cfg = get_arch("bloom-3b").scaled(n_layers=2, d_model=256, n_heads=8,
                                      n_kv_heads=8, d_ff=1024, vocab=2048)
    engine = ServingEngine(cfg, batch_capacity=max(len(chosen), 1),
                           s_max=64, n_max=16, quant_bits=8)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=min(r.s, 64)).tolist()
               for r in chosen]
    result = engine.generate(prompts, [min(r.n, 16) for r in chosen])
    print(f"\nexecuted DFTSP batch on a reduced BLOOM (W8 Pallas matmuls): "
          f"{result.batch} requests, {int(result.lengths.sum())} tokens "
          f"generated")
    print("first output:", result.tokens[0][:result.lengths[0]].tolist())


if __name__ == "__main__":
    main()
