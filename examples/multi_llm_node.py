"""Multi-LLM edge node: one EN hosting BLOOM-3B + BLOOM-7.1B (paper §II's
"adaptable for multiple LLMs" remark, made concrete) — served on the
CONTINUOUS path with real engines.

Requests arrive tagged for a model (``Request.model_id``).  The joint
``multi-dftsp`` policy — built from the same registry as the
single-model schedulers — first shows one epoch of joint batch
selection against the SHARED memory/compute/spectrum budgets; then the
node serves frozen traffic end to end through
``ContinuousRuntime`` + ``EngineContinuousExecutor``: one device-resident
cohort per hosted engine, admission at every chunked-segment boundary
gated by the joint ``multi_feasible`` oracle, and each fresh cohort's
quantization method picked by the ``quant=auto`` descent and served via
the engines' multi-precision weight caches.

  PYTHONPATH=src python examples/multi_llm_node.py
"""
from __future__ import annotations

from repro.core import problem
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, random_tagger, tag
from repro.core.policy import get_policy
from repro.core.request import ReplayGenerator, RequestGenerator
from repro.serving.engine import tiny_engine
from repro.serving.runtime import (ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime)

HOSTED = ("bloom-3b", "bloom-7b1")


def make_engines(seed=0):
    """One reduced real engine per hosted model (CPU-sized)."""
    return {arch: tiny_engine(arch, batch_capacity=8, s_max=16, n_max=16,
                              seed=seed) for arch in HOSTED}


def joint_schedule_demo(menv):
    """One epoch of joint batch selection (the analytic control plane)."""
    gen = RequestGenerator(rate=40, seed=0)
    reqs = gen.within(0, 2.0)
    half = len(reqs) // 2
    pool = tag(reqs[:half], "bloom-3b") + tag(reqs[half:], "bloom-7b1")
    print(f"{len(pool)} requests in one epoch "
          f"({half} -> bloom-3b, {len(pool) - half} -> bloom-7b1)")

    policy = get_policy("multi-dftsp:order=weight")
    decision = policy.schedule(menv, pool)
    assert policy.validate(menv, decision)
    for mid, batch in decision.batches.items():
        env = menv.envs[mid]
        t = problem.batch_compute_time(env, batch) if batch else 0.0
        print(f"  {mid:10s}: {len(batch):2d} scheduled, "
              f"batch compute {t * 1e3:6.1f} ms")
    print(f"total {decision.stats.z_solved} served this epoch "
          f"({decision.stats.nodes_visited} nodes searched)")


def continuous_serving_demo(menv, n_epochs=6, rate=8.0, k=2):
    """Both protocols on identical frozen traffic, real engines."""
    tagger = random_tagger(sorted(menv.envs), seed=0)
    traffic = ReplayGenerator.poisson(rate, (n_epochs - 1) * menv.T_E,
                                      seed=0, lengths=(4, 8, 16))

    epoch = EpochRuntime(menv, "multi-dftsp",
                         EngineExecutor(make_engines(), seed=0)).run(
        gen=ReplayGenerator(traffic.requests), n_epochs=n_epochs, seed=0,
        warmup_epochs=0, tag_arrivals=tagger)
    runtime = ContinuousRuntime(
        menv, "multi-dftsp:quant=auto",
        EngineContinuousExecutor(make_engines(), seed=0), k=k)
    cont = runtime.run(gen=ReplayGenerator(traffic.requests),
                       n_epochs=n_epochs, seed=0, warmup_epochs=0,
                       tag_arrivals=tagger)

    print(f"\n  {'':24s}{'epoch-boundary':>16s}{'continuous':>14s}")
    for label, a, b in (
            ("served", epoch.served, cont.served),
            ("req/s", f"{epoch.throughput:.2f}", f"{cont.throughput:.2f}"),
            ("mid-epoch admissions", 0, cont.admitted_mid_epoch),
            ("mean slot occupancy", "-", f"{cont.mean_occupancy:.2f}")):
        print(f"  {label:24s}{str(a):>16s}{str(b):>14s}")
    print(f"  continuous speedup: {cont.served / max(epoch.served, 1):.2f}x "
          f"({runtime.segments_per_epoch} admission points per epoch vs 1)")
    print("\n  per-model served (continuous): "
          + ", ".join(f"{m}: {n}"
                      for m, n in sorted(cont.served_by_model.items())))
    print("  served by method (quant=auto): "
          + ", ".join(f"{m}: {n}"
                      for m, n in sorted(cont.served_by_method.items())))
    print("  per-epoch cohort methods:")
    for t in cont.traces:
        if t.quants:
            sel = " ".join(f"{m}={q}" for m, q in sorted(t.quants.items()))
            print(f"    epoch {t.epoch}: {sel}")


def main():
    menv = MultiLLMEnv.host({m: paper_env(m, "W8A16") for m in HOSTED})
    print(f"edge node hosts {len(HOSTED)} LLMs; resident weights "
          f"{menv.weight_bytes() / 1e9:.1f} GB of {menv.M / 1e9:.0f} GB")
    joint_schedule_demo(menv)
    continuous_serving_demo(menv)


if __name__ == "__main__":
    main()
