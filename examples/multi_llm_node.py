"""Multi-LLM edge node: one EN hosting BLOOM-3B + BLOOM-7.1B (paper §II's
"adaptable for multiple LLMs" remark, made concrete).

Requests arrive tagged for a model (``Request.model_id``); the joint
``multi-dftsp`` policy — built from the same registry as the single-model
schedulers — runs DFTSP per model against the SHARED
memory/compute/spectrum budgets, with earlier batches' compute queueing
in front of later ones (single T_C slot).

  PYTHONPATH=src python examples/multi_llm_node.py
"""
from __future__ import annotations

from repro.core import problem
from repro.core.environment import paper_env
from repro.core.multi import MultiLLMEnv, tag
from repro.core.policy import get_policy
from repro.core.request import RequestGenerator


def main():
    menv = MultiLLMEnv.host({
        "bloom-3b": paper_env("bloom-3b", "W8A16"),
        "bloom-7b1": paper_env("bloom-7b1", "W8A16"),
    })
    print(f"edge node hosts 2 LLMs; resident weights "
          f"{menv.weight_bytes() / 1e9:.1f} GB of {menv.M / 1e9:.0f} GB")

    gen = RequestGenerator(rate=40, seed=0)
    reqs = gen.within(0, 2.0)
    half = len(reqs) // 2
    pool = tag(reqs[:half], "bloom-3b") + tag(reqs[half:], "bloom-7b1")
    print(f"{len(pool)} requests in one epoch "
          f"({half} -> bloom-3b, {len(pool) - half} -> bloom-7b1)")

    policy = get_policy("multi-dftsp:order=weight")
    decision = policy.schedule(menv, pool)
    assert policy.validate(menv, decision)
    stats = decision.stats
    for mid, batch in decision.batches.items():
        env = menv.envs[mid]
        t = problem.batch_compute_time(env, batch) if batch else 0.0
        print(f"  {mid:10s}: {len(batch):2d} scheduled, "
              f"batch compute {t * 1e3:6.1f} ms")
    print(f"total {stats.z_solved} served this epoch "
          f"({stats.nodes_visited} nodes searched)")

    # contrast: the same node dedicating everything to one model
    solo = policy.schedule(MultiLLMEnv.host(
        {"bloom-3b": menv.envs["bloom-3b"]}), tag(list(reqs), "bloom-3b"))
    print(f"(single-model reference: {solo.size} "
          f"of the same {len(reqs)} requests)")


if __name__ == "__main__":
    main()
