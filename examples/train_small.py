"""Train a ~small LM for a few hundred steps (deliverable b, training kind).

Uses the full training substrate: synthetic bigram corpus, AdamW with
warmup+cosine, remat, checkpointing.  ~100M-class config by default
(12 layers x 512) scaled down further with --tiny for CI.

  PYTHONPATH=src python examples/train_small.py --steps 300
  PYTHONPATH=src python examples/train_small.py --tiny --steps 30
"""
from __future__ import annotations

import argparse

from repro.config import get_arch
from repro.train import Trainer
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    if args.tiny:
        red = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                   d_ff=256, vocab=512)
        batch, seq = 8, 64
    else:
        # ~100M params: 12 x 512 with 8k vocab
        red = dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
                   d_ff=2048, vocab=8192)
        batch, seq = 16, 256
    cfg = get_arch(args.arch).scaled(**red)
    print(f"[train_small] {cfg.arch_id} reduced to "
          f"{cfg.param_count() / 1e6:.1f}M params")

    tr = Trainer(cfg, batch=batch, seq=seq,
                 opt_cfg=AdamWConfig(lr=3e-4, warmup_steps=20,
                                     total_steps=args.steps),
                 remat=not args.tiny)
    state, hist = tr.run(args.steps, log_every=max(args.steps // 20, 1),
                         checkpoint_path=args.checkpoint)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train_small] loss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'did not decrease!'})")


if __name__ == "__main__":
    main()
