"""End-to-end edge serving driver (the paper's full loop, deliverable b).

Multi-epoch serving of a small model with batched requests: Poisson
arrivals -> queue aging + deadline drops -> DFTSP schedule -> real batched
prefill+decode on JAX with quantized weights -> per-epoch accounting.

Both the real-engine run and the analytic cross-check drive the SAME
``EpochRuntime`` control loop — only the Executor differs.

  PYTHONPATH=src python examples/serve_edge.py [--epochs 6] [--rate 12]
"""
from __future__ import annotations

import argparse

from repro.config import get_arch
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.serving.engine import ServingEngine
from repro.serving.runtime import (AnalyticExecutor, EngineExecutor,
                                   EpochRuntime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--rate", type=float, default=12.0)
    ap.add_argument("--scheduler", default="dftsp")
    ap.add_argument("--quant-bits", type=int, default=8)
    args = ap.parse_args()

    env = paper_env("bloom-3b", "W8A16")
    policy = get_policy(args.scheduler)
    cfg = get_arch("bloom-3b").scaled(n_layers=2, d_model=256, n_heads=8,
                                      n_kv_heads=8, d_ff=1024, vocab=2048)
    engine = ServingEngine(cfg, batch_capacity=8, s_max=64, n_max=16,
                           quant_bits=args.quant_bits)

    print(f"[serve_edge] executing {args.epochs} epochs at rate "
          f"{args.rate}/s with {policy.spec} (W{args.quant_bits or 16})")
    runtime = EpochRuntime(env, policy, EngineExecutor(engine, seed=0))
    trace = runtime.run(rate=args.rate, n_epochs=args.epochs, seed=0,
                        warmup_epochs=0)
    print(f"  served      : {trace.served} requests")
    print(f"  tokens      : {trace.generated_tokens}")
    print(f"  batch sizes : {trace.batch_sizes}")
    print(f"  truncated   : {trace.truncated} (spilled past engine capacity)")
    print(f"  throughput  : {trace.throughput:.2f} req/s")

    # cross-check against the long-horizon analytic simulation (same loop,
    # AnalyticExecutor data plane)
    res = EpochRuntime(env, policy, AnalyticExecutor()).run(
        rate=args.rate, n_epochs=30, seed=0)
    print(f"[analytic 30-epoch] throughput {res.throughput:.2f} req/s, "
          f"mean batch {res.mean_batch:.1f}, dropped {res.dropped}")


if __name__ == "__main__":
    main()
