"""Quantization as a scheduling decision: adaptive vs fixed methods.

Part 1 measures — not assumes — the paper's alpha and dPPL on an actual
JAX model: quantize the weights at W8/W4, measure the memory ratio and
the perplexity differential on a held-out synthetic set, and feed the
measured dPPL into the scheduler's accuracy constraint (1e).

Part 2 is the point of the refactor: the SAME trade-off as a live control
decision.  ``dftsp:quant=auto`` picks the throughput-optimal admissible
method per epoch, and on a mixed accuracy-requirement workload beats
every fixed deployment from METHODS.

  PYTHONPATH=src python examples/quantization_tradeoff.py
"""
from __future__ import annotations

from repro.config import get_arch
from repro.core.environment import paper_env
from repro.core.policy import get_policy
from repro.core.quantization import METHODS, QuantMethod, f_accuracy
from repro.core.request import RequestGenerator
from repro.serving.runtime import AnalyticExecutor, EpochRuntime


def simulate(env, spec, rate=50, n_epochs=10, seed=0, acc_range=(0.0, 1.0)):
    gen = RequestGenerator(rate=rate, seed=seed, acc_range=acc_range)
    return EpochRuntime(env, get_policy(spec), AnalyticExecutor()).run(
        n_epochs=n_epochs, seed=seed, gen=gen)


def measured_methods():
    """Calibrate alpha/dPPL on a reduced bloom-3b (paper §II-B.3, live)."""
    from repro.models.api import build_model
    from repro.quant.calibration import calibrate
    from repro.train import Trainer
    import jax.numpy as jnp

    cfg = get_arch("bloom-3b").scaled(n_layers=4, d_model=256, n_heads=8,
                                      n_kv_heads=8, d_ff=1024, vocab=2048)
    build_model(cfg)
    print(f"[calibrate] reduced bloom-3b: {cfg.param_count() / 1e6:.1f}M "
          f"params — pre-training briefly so PPL (and dPPL) are "
          f"meaningful\n")
    tr = Trainer(cfg, batch=16, seq=64)
    state, _ = tr.run(150, log_every=50, log=lambda s: None)
    params = state.params
    # held-out batch from the SAME corpus the model was trained on
    eval_batch = {k: jnp.asarray(v) for k, v in tr.data.next_batch().items()}

    out = []
    for bits in (8, 4):
        rec = calibrate(cfg, params, bits=bits, batch=eval_batch)
        print(f"W{bits}: measured alpha_w={rec['alpha_w']:.3f} "
              f"(paper predicts {bits / 16:.3f}), "
              f"PPL {rec['ppl_fp']:.1f} -> {rec['ppl_quant']:.1f} "
              f"(dPPL={rec['dppl']:+.3f})")
        dppl = max(rec["dppl"], 0.0)
        out.append(QuantMethod(f"W{bits}-measured", bits, 16,
                               beta=0.85 if bits == 8 else 0.8,
                               dppl_default=dppl))
    return out


def main():
    # -- Part 1: measure the trade-off on a real model -----------------------
    for method in measured_methods():
        f = f_accuracy(method.dppl_default)
        env = paper_env("bloom-3b").with_(quant=method)
        res = simulate(env, "dftsp")
        print(f"  {method.name}: f(dPPL)={f:.3f} -> serves users with "
              f"a<= that; throughput {res.throughput:.2f} req/s")

    # -- Part 2: the trade-off as a per-epoch scheduling decision ------------
    print("\nadaptive method selection (dftsp:quant=auto) vs every fixed "
          "deployment,\nmixed accuracy demands a ~ U(0,1), rate 50 req/s:")
    env = paper_env("bloom-3b")
    rows = []
    for name in METHODS:
        res = simulate(env, f"dftsp:quant={name}")
        rows.append((name, res.throughput, ""))
    auto = simulate(env, "dftsp:quant=auto")
    mix = ", ".join(f"{k}:{v}" for k, v in
                    sorted(auto.served_by_method.items()))
    rows.append(("quant=auto", auto.throughput, f"served mix: {mix}"))
    best_fixed = max(t for _, t, _ in rows[:-1])
    for name, thr, note in rows:
        mark = " <= auto" if name != "quant=auto" else ""
        print(f"  {name:12s} {thr:6.2f} req/s{mark}  {note}")
    print(f"\n[demo] auto {auto.throughput:.2f} req/s vs best fixed "
          f"{best_fixed:.2f} req/s — the Fig. 6 frontier, live per epoch")
    assert auto.throughput >= best_fixed - 1e-9


if __name__ == "__main__":
    main()
