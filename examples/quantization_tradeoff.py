"""Quantization accuracy/throughput tradeoff on REAL models (deliverable b).

Measures — not assumes — the paper's alpha and dPPL on an actual JAX
model: quantize the weights at W8/W4, measure memory ratio and perplexity
differential on a held-out synthetic set, then show how the measured dPPL
feeds the scheduler's accuracy constraint (1e).

  PYTHONPATH=src python examples/quantization_tradeoff.py
"""
from __future__ import annotations

import math

import jax

from repro.config import get_arch
from repro.core.environment import paper_env
from repro.core.epoch import simulate
from repro.core.quantization import QuantMethod, f_accuracy
from repro.models.api import build_model
from repro.quant.calibration import calibrate


def main():
    cfg = get_arch("bloom-3b").scaled(n_layers=4, d_model=256, n_heads=8,
                                      n_kv_heads=8, d_ff=1024, vocab=2048)
    model = build_model(cfg)
    print(f"[calibrate] reduced bloom-3b: {cfg.param_count() / 1e6:.1f}M "
          f"params — pre-training briefly so PPL (and dPPL) are "
          f"meaningful\n")
    from repro.train import Trainer
    import jax.numpy as jnp
    tr = Trainer(cfg, batch=16, seq=64)
    state, _ = tr.run(150, log_every=50, log=lambda s: None)
    params = state.params
    # held-out batch from the SAME corpus the model was trained on
    eval_batch = {k: jnp.asarray(v) for k, v in tr.data.next_batch().items()}

    records = {}
    for bits in (8, 4):
        rec = calibrate(cfg, params, bits=bits, batch=eval_batch)
        records[bits] = rec
        print(f"W{bits}: measured alpha_w={rec['alpha_w']:.3f} "
              f"(paper predicts {bits / 16:.3f}), "
              f"PPL {rec['ppl_fp']:.1f} -> {rec['ppl_quant']:.1f} "
              f"(dPPL={rec['dppl']:+.3f})")

    # feed the MEASURED dPPL into the scheduler's accuracy model
    print("\nscheduler impact (accuracy constraint 1e, f = exp(-dPPL)):")
    for bits in (8, 4):
        dppl = max(records[bits]["dppl"], 0.0)
        f = f_accuracy(dppl)
        method = QuantMethod(f"W{bits}-measured", bits, 16,
                             beta=0.85 if bits == 8 else 0.8,
                             dppl_default=dppl)
        env = paper_env("bloom-3b").with_(quant=method)
        res = simulate(env, "dftsp", rate=50, n_epochs=10, seed=0)
        print(f"  W{bits}: f(dPPL)={f:.3f} -> serves users with a<= that; "
              f"throughput {res.throughput:.2f} req/s")


if __name__ == "__main__":
    main()
