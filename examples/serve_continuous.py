"""Continuous-batching serving demo: mid-epoch admission on a real engine.

Runs the SAME frozen traffic through the epoch-boundary protocol
(``EpochRuntime`` + ``EngineExecutor``) and the continuous-batching path
(``ContinuousRuntime`` + ``EngineContinuousExecutor``), then shows where
the extra throughput comes from: every epoch, slots freed by finished
rows are refilled at chunked-segment boundaries instead of idling until
the next epoch — with every refill still gated by the scheduler policy's
own P1 feasibility oracle (``policy.validate``).

  PYTHONPATH=src python examples/serve_continuous.py [--epochs 6]
      [--rate 8] [--k 2] [--scheduler dftsp]
"""
from __future__ import annotations

import argparse

from repro.config import get_arch
from repro.core.environment import paper_env
from repro.core.request import ReplayGenerator
from repro.serving.engine import ServingEngine
from repro.serving.runtime import (ContinuousRuntime,
                                   EngineContinuousExecutor, EngineExecutor,
                                   EpochRuntime)


def make_engine(params=None):
    cfg = get_arch("bloom-3b").scaled(n_layers=2, d_model=128, n_heads=4,
                                      n_kv_heads=4, d_ff=256, vocab=512)
    return ServingEngine(cfg, params=params, batch_capacity=8, s_max=32,
                         n_max=16)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--k", type=int, default=2,
                    help="decode tokens per chunked segment")
    ap.add_argument("--scheduler", default="dftsp")
    args = ap.parse_args()

    env = paper_env("bloom-3b", "W8A16")
    # freeze one Poisson stream, cut at the epoch protocol's last
    # admission boundary, so both protocols see identical traffic
    traffic = ReplayGenerator.poisson(args.rate,
                                      (args.epochs - 1) * env.T_E, seed=0,
                                      lengths=(4, 8, 16))

    engine = make_engine()
    print(f"[serve_continuous] {args.epochs} epochs at rate {args.rate}/s, "
          f"{args.scheduler}, chunk k={args.k}")
    epoch = EpochRuntime(env, args.scheduler,
                         EngineExecutor(engine, seed=0)).run(
        gen=ReplayGenerator(traffic.requests), n_epochs=args.epochs,
        seed=0, warmup_epochs=0)

    runtime = ContinuousRuntime(
        env, args.scheduler,
        EngineContinuousExecutor(make_engine(engine._raw_params), seed=0),
        k=args.k)
    cont = runtime.run(gen=ReplayGenerator(traffic.requests),
                       n_epochs=args.epochs, seed=0, warmup_epochs=0)

    print(f"\n  {'':24s}{'epoch-boundary':>16s}{'continuous':>14s}")
    for label, a, b in (
            ("served", epoch.served, cont.served),
            ("dropped", epoch.dropped, cont.dropped),
            ("req/s", f"{epoch.throughput:.2f}", f"{cont.throughput:.2f}"),
            ("generated tokens", epoch.generated_tokens,
             cont.generated_tokens),
            ("decode tok/s", f"{epoch.tokens_per_s:.0f}",
             f"{cont.tokens_per_s:.0f}"),
            ("mid-epoch admissions", 0, cont.admitted_mid_epoch),
            ("mean slot occupancy", "-", f"{cont.mean_occupancy:.2f}")):
        print(f"  {label:24s}{str(a):>16s}{str(b):>14s}")
    print(f"\n  continuous speedup: "
          f"{cont.served / max(epoch.served, 1):.2f}x req/s "
          f"({runtime.segments_per_epoch} admission points per epoch "
          f"vs 1)")

    print("\n  per-epoch continuous trace "
          "(admitted@interior-boundaries / occupancy):")
    for t in cont.traces:
        occ = sum(t.occupancy) / len(t.occupancy) if t.occupancy else 0.0
        print(f"    epoch {t.epoch}: arrived={t.arrived:3d} "
              f"admitted={len(t.selected_rids):3d} "
              f"(mid-epoch {t.admitted_mid_epoch:3d}) "
              f"finished={len(t.finished_rids):3d} "
              f"dropped={t.dropped:3d} occupancy={occ:.2f}")


if __name__ == "__main__":
    main()
